//! The Harvest controller: allocation, data movement, pressure watching,
//! and the ordered revocation pipeline (§3.2).
//!
//! Lifecycle of a cached object:
//!
//! 1. `harvest_alloc(size, hints)` — the controller builds peer views,
//!    asks the [`PlacementPolicy`] for a peer, allocates in that peer's
//!    HBM arena (standard CUDA allocation path stand-in) and returns a
//!    [`HarvestHandle`].
//! 2. The application moves data explicitly (`copy_in` / `fetch_to` —
//!    `cudaMemcpyPeerAsync` stand-ins tagged with the handle).
//! 3. On revocation (tenant pressure, MIG reclaim, policy eviction, or
//!    explicit free) the controller **first drains in-flight DMA touching
//!    the region, then invalidates the placement entry, then fires the
//!    registered callback** — exactly the §3.2 ordering.
//!
//! The controller never tracks dirty state and never writes back: the
//! handle's [`Durability`] only tells the *application's* callback what
//! fallback is legal.

use super::api::{AllocHints, HandleId, HarvestError, HarvestHandle, Revocation, RevocationReason};
use super::mig::MigConfig;
use super::monitor::PeerMonitor;
use super::policy::{BestFit, PlacementPolicy, PlacementRequest};
use crate::memsim::{CopyEvent, DeviceId, Ns, SimNode};
use std::collections::BTreeMap;

/// Which live allocations die first under pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VictimPolicy {
    /// Newest first (default: oldest entries have proven useful).
    #[default]
    Lifo,
    /// Oldest first.
    Fifo,
    /// Largest first (frees the most with the fewest callbacks).
    LargestFirst,
    /// Smallest first.
    SmallestFirst,
}

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct HarvestConfig {
    pub victim_policy: VictimPolicy,
    /// Per-GPU MIG partitioning (defaults to disabled everywhere).
    pub mig: Vec<MigConfig>,
    /// Sliding window for churn/bandwidth monitoring.
    pub monitor_window: Ns,
    /// Headroom kept free for tenants on every peer: the controller
    /// revokes once tenant usage pushes free space under this reserve.
    pub reserve_bytes: u64,
}

impl HarvestConfig {
    pub fn for_node(n_gpus: usize) -> Self {
        Self {
            victim_policy: VictimPolicy::default(),
            mig: vec![MigConfig::Disabled; n_gpus],
            monitor_window: 1_000_000_000,
            reserve_bytes: 0,
        }
    }
}

type Callback = Box<dyn FnMut(&Revocation)>;

/// The runtime. Owns the simulated node; subsystems (MoE rebalancer, KV
/// manager) drive it single-threadedly.
pub struct HarvestRuntime {
    pub node: SimNode,
    policy: Box<dyn PlacementPolicy>,
    pub config: HarvestConfig,
    monitor: PeerMonitor,
    live: BTreeMap<HandleId, HarvestHandle>,
    /// Incremental accounting: our live bytes per peer, and per
    /// (peer, client) for the fairness ledger — avoids an O(live)
    /// scan on every allocation (EXPERIMENTS.md §Perf).
    bytes_on: Vec<u64>,
    client_bytes: BTreeMap<(usize, u32), u64>,
    /// Allocation order per peer (for LIFO/FIFO victim selection):
    /// insertion-sequence -> handle, O(log n) removal on free/revoke.
    order: Vec<BTreeMap<u64, HandleId>>,
    order_key: BTreeMap<HandleId, u64>,
    next_order: u64,
    callbacks: BTreeMap<HandleId, Callback>,
    next_handle: u64,
    /// Every completed revocation, in order (for tests/metrics).
    pub revocations: Vec<Revocation>,
    /// Cumulative counters.
    pub alloc_attempts: u64,
    pub alloc_failures: u64,
}

impl HarvestRuntime {
    pub fn new(node: SimNode, config: HarvestConfig) -> Self {
        Self::with_policy(node, config, Box::new(BestFit))
    }

    pub fn with_policy(
        node: SimNode,
        config: HarvestConfig,
        policy: Box<dyn PlacementPolicy>,
    ) -> Self {
        assert_eq!(config.mig.len(), node.n_gpus(), "one MigConfig per GPU");
        let n = node.n_gpus();
        let monitor = PeerMonitor::new(n, config.monitor_window);
        Self {
            node,
            policy,
            config,
            monitor,
            live: BTreeMap::new(),
            bytes_on: vec![0; n],
            client_bytes: BTreeMap::new(),
            order: vec![BTreeMap::new(); n],
            order_key: BTreeMap::new(),
            next_order: 0,
            callbacks: BTreeMap::new(),
            next_handle: 0,
            revocations: Vec::new(),
            alloc_attempts: 0,
            alloc_failures: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn live_handles(&self) -> impl Iterator<Item = &HarvestHandle> {
        self.live.values()
    }

    pub fn live_bytes_on(&self, peer: usize) -> u64 {
        self.bytes_on[peer]
    }

    pub fn is_live(&self, id: HandleId) -> bool {
        self.live.contains_key(&id)
    }

    fn partition_limits(&self) -> Vec<Option<u64>> {
        self.config.mig.iter().map(|m| m.harvest_limit()).collect()
    }

    fn views_for(&mut self, client: Option<u32>) -> Vec<super::monitor::PeerView> {
        self.monitor.observe(&self.node);
        let limits = self.partition_limits();
        let ours: Vec<u64> = (0..self.node.n_gpus())
            .map(|p| match client {
                None => self.bytes_on[p],
                Some(c) => self.client_bytes.get(&(p, c)).copied().unwrap_or(0),
            })
            .collect();
        self.monitor.views(&self.node, &limits, &ours)
    }

    /// Bookkeeping shared by alloc and the two removal paths.
    fn account_add(&mut self, h: &HarvestHandle) {
        self.bytes_on[h.peer] += h.size;
        if let Some(c) = h.client {
            *self.client_bytes.entry((h.peer, c)).or_insert(0) += h.size;
        }
    }

    fn account_remove(&mut self, h: &HarvestHandle) {
        self.bytes_on[h.peer] -= h.size;
        if let Some(c) = h.client {
            if let Some(b) = self.client_bytes.get_mut(&(h.peer, c)) {
                *b -= h.size;
                if *b == 0 {
                    self.client_bytes.remove(&(h.peer, c));
                }
            }
        }
    }

    /// §3.2 `harvest_alloc`: select a peer and allocate.
    pub fn alloc(&mut self, size: u64, hints: AllocHints) -> Result<HarvestHandle, HarvestError> {
        self.alloc_attempts += 1;
        if size == 0 {
            self.alloc_failures += 1;
            return Err(HarvestError::ZeroSize);
        }
        let views = self.views_for(hints.client);
        let peer = if let Some(p) = hints.prefer_peer {
            let ok = p < views.len()
                && views[p].harvestable >= size
                && views[p].largest_free >= size
                && Some(p) != hints.compute_gpu
                && self.config.mig[p].allows_harvest();
            if !ok {
                self.alloc_failures += 1;
                return Err(HarvestError::PeerUnavailable { peer: p });
            }
            p
        } else {
            // Filter P2P-restricted devices before the policy sees them.
            let views: Vec<_> = views
                .into_iter()
                .filter(|v| self.config.mig[v.device].allows_harvest())
                .collect();
            let req = PlacementRequest { size, hints, views: &views, topo: &self.node.topo };
            match self.policy.select(&req) {
                Some(p) => p,
                None => {
                    self.alloc_failures += 1;
                    return Err(HarvestError::NoCapacity { requested: size });
                }
            }
        };
        let alloc = self.node.gpus[peer].hbm.alloc(size).map_err(|_| {
            self.alloc_failures += 1;
            HarvestError::NoCapacity { requested: size }
        })?;
        let offset = self.node.gpus[peer].hbm.offset_of(alloc).unwrap();
        let handle = HarvestHandle {
            id: HandleId(self.next_handle),
            peer,
            alloc,
            offset,
            size,
            durability: hints.durability,
            client: hints.client,
        };
        self.next_handle += 1;
        self.live.insert(handle.id, handle);
        self.account_add(&handle);
        let k = self.next_order;
        self.next_order += 1;
        self.order[peer].insert(k, handle.id);
        self.order_key.insert(handle.id, k);
        Ok(handle)
    }

    /// §3.2 `harvest_register_cb`.
    pub fn register_cb(
        &mut self,
        id: HandleId,
        cb: impl FnMut(&Revocation) + 'static,
    ) -> Result<(), HarvestError> {
        if !self.live.contains_key(&id) {
            return Err(HarvestError::StaleHandle(id));
        }
        self.callbacks.insert(id, Box::new(cb));
        Ok(())
    }

    /// §3.2 `harvest_free`: explicit, ordered deallocation (drains DMA
    /// first; does NOT fire the revocation callback — the app initiated
    /// the free).
    pub fn free(&mut self, id: HandleId) -> Result<(), HarvestError> {
        let handle = self.live.remove(&id).ok_or(HarvestError::StaleHandle(id))?;
        self.account_remove(&handle);
        self.node.dma.drain_tag(&self.node.topo, id.0);
        self.node.gpus[handle.peer].hbm.free(handle.alloc);
        if let Some(k) = self.order_key.remove(&id) {
            self.order[handle.peer].remove(&k);
        }
        self.callbacks.remove(&id);
        Ok(())
    }

    /// Populate the peer cache: async copy `handle.size` bytes from `src`
    /// into the peer allocation.
    pub fn copy_in(&mut self, id: HandleId, src: DeviceId) -> Result<CopyEvent, HarvestError> {
        let h = *self.live.get(&id).ok_or(HarvestError::StaleHandle(id))?;
        let ev = self.node.copy(src, DeviceId::Gpu(h.peer), h.size, Some(id.0));
        self.monitor.record_transfer(h.peer, ev.end, h.size);
        Ok(ev)
    }

    /// Serve a cache hit: async copy the object from its peer to the
    /// compute GPU. This is the fast path the paper measures.
    pub fn fetch_to(&mut self, id: HandleId, compute: usize) -> Result<CopyEvent, HarvestError> {
        let h = *self.live.get(&id).ok_or(HarvestError::StaleHandle(id))?;
        let ev = self.node.copy(DeviceId::Gpu(h.peer), DeviceId::Gpu(compute), h.size, Some(id.0));
        self.monitor.record_transfer(h.peer, ev.end, h.size);
        Ok(ev)
    }

    /// The revocation pipeline for one handle. Ordering per §3.2:
    /// drain in-flight DMA → free + invalidate → fire callback.
    pub fn revoke(&mut self, id: HandleId, reason: RevocationReason) -> Option<Revocation> {
        let handle = self.live.remove(&id)?;
        self.account_remove(&handle);
        // 1. Drain: advance virtual time past every op touching the region.
        let drained_at = self.node.dma.drain_tag(&self.node.topo, id.0);
        // 2. Invalidate + free.
        self.node.gpus[handle.peer].hbm.free(handle.alloc);
        if let Some(k) = self.order_key.remove(&id) {
            self.order[handle.peer].remove(&k);
        }
        let rev = Revocation { handle, reason, at: drained_at };
        self.revocations.push(rev);
        // 3. Callback (exactly once; the entry is gone from `live`).
        if let Some(mut cb) = self.callbacks.remove(&id) {
            cb(&rev);
        }
        Some(rev)
    }

    /// Revoke everything on `peer` (e.g. MIG instance reclaimed).
    pub fn revoke_peer(&mut self, peer: usize, reason: RevocationReason) -> Vec<Revocation> {
        let ids: Vec<HandleId> = self.order[peer].values().copied().collect();
        ids.into_iter().rev().filter_map(|id| self.revoke(id, reason)).collect()
    }

    fn pick_victim(&self, peer: usize) -> Option<HandleId> {
        let order = &self.order[peer];
        match self.config.victim_policy {
            VictimPolicy::Lifo => order.last_key_value().map(|(_, &id)| id),
            VictimPolicy::Fifo => order.first_key_value().map(|(_, &id)| id),
            VictimPolicy::LargestFirst => {
                order.values().max_by_key(|id| self.live[id].size).copied()
            }
            VictimPolicy::SmallestFirst => {
                order.values().min_by_key(|id| self.live[id].size).copied()
            }
        }
    }

    /// Enforce capacity on every peer at the current virtual time:
    /// while co-tenant demand + our allocations + reserve exceed
    /// capacity (or a MIG partition shrank), revoke victims. Returns the
    /// revocations performed.
    pub fn enforce_pressure(&mut self) -> Vec<Revocation> {
        let now = self.node.clock.now();
        let mut out = Vec::new();
        for peer in 0..self.node.n_gpus() {
            loop {
                let cap = self.node.gpus[peer].hbm.capacity();
                let tenant = self.node.gpus[peer].tenant.used_at(now);
                let ours = self.node.gpus[peer].hbm.used();
                let budget = cap.saturating_sub(tenant).saturating_sub(self.config.reserve_bytes);
                let limit = self.config.mig[peer].harvest_limit().unwrap_or(u64::MAX);
                if ours <= budget.min(limit) {
                    break;
                }
                let Some(victim) = self.pick_victim(peer) else { break };
                if let Some(rev) = self.revoke(victim, RevocationReason::TenantPressure) {
                    out.push(rev);
                }
            }
        }
        self.monitor.observe(&self.node);
        out
    }

    /// Advance virtual time to `t`, enforcing pressure at every tenant
    /// change in between (so revocations happen when capacity disappears,
    /// not when someone next allocates). Returns all revocations.
    pub fn advance_to(&mut self, t: Ns) -> Vec<Revocation> {
        let mut out = Vec::new();
        loop {
            let now = self.node.clock.now();
            let next_change = self
                .node
                .gpus
                .iter()
                .filter_map(|g| g.tenant.next_change_after(now))
                .map(|e| e.at)
                .min();
            match next_change {
                Some(at) if at <= t => {
                    self.node.clock.advance_to(at);
                    out.extend(self.enforce_pressure());
                }
                _ => break,
            }
        }
        self.node.clock.advance_to(t);
        out.extend(self.enforce_pressure());
        out
    }

    /// Policy views at now (for introspection / examples).
    pub fn peer_views(&mut self) -> Vec<super::monitor::PeerView> {
        self.views_for(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::NodeSpec;
    use std::cell::RefCell;
    use std::rc::Rc;

    const GIB: u64 = 1 << 30;
    const MIB: u64 = 1 << 20;

    fn rt() -> HarvestRuntime {
        let node = SimNode::new(NodeSpec::h100x2());
        let config = HarvestConfig::for_node(2);
        HarvestRuntime::new(node, config)
    }

    fn hints(compute: usize) -> AllocHints {
        AllocHints { compute_gpu: Some(compute), ..Default::default() }
    }

    #[test]
    fn alloc_places_on_peer_not_compute() {
        let mut h = rt();
        let handle = h.alloc(100 * MIB, hints(0)).unwrap();
        assert_eq!(handle.peer, 1);
        assert_eq!(handle.size, 100 * MIB);
        assert!(h.is_live(handle.id));
        assert_eq!(h.live_bytes_on(1), 100 * MIB);
    }

    #[test]
    fn alloc_respects_tenant_capacity() {
        let mut h = rt();
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 79 * GIB));
        match h.alloc(2 * GIB, hints(0)) {
            Err(HarvestError::NoCapacity { .. }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(h.alloc_failures, 1);
    }

    #[test]
    fn pinned_peer_honoured_or_rejected() {
        let mut h = rt();
        let hint = AllocHints { prefer_peer: Some(1), ..hints(0) };
        let handle = h.alloc(MIB, hint).unwrap();
        assert_eq!(handle.peer, 1);
        // pinning the compute GPU itself is rejected
        let bad = AllocHints { prefer_peer: Some(0), ..hints(0) };
        assert!(matches!(h.alloc(MIB, bad), Err(HarvestError::PeerUnavailable { peer: 0 })));
    }

    #[test]
    fn explicit_free_releases_and_skips_callback() {
        let mut h = rt();
        let handle = h.alloc(MIB, hints(0)).unwrap();
        let fired = Rc::new(RefCell::new(0));
        let f2 = fired.clone();
        h.register_cb(handle.id, move |_| *f2.borrow_mut() += 1).unwrap();
        h.free(handle.id).unwrap();
        assert!(!h.is_live(handle.id));
        assert_eq!(*fired.borrow(), 0, "explicit free must not fire revocation cb");
        assert_eq!(h.node.gpus[1].hbm.used(), 0);
        // double free reports stale handle
        assert!(matches!(h.free(handle.id), Err(HarvestError::StaleHandle(_))));
    }

    #[test]
    fn revocation_order_drain_then_invalidate_then_callback() {
        let mut h = rt();
        let handle = h.alloc(64 * MIB, hints(0)).unwrap();
        // start a long copy touching the region
        let ev = h.copy_in(handle.id, DeviceId::Host).unwrap();
        assert!(ev.end > h.node.clock.now(), "copy is in flight");
        let observed = Rc::new(RefCell::new(None));
        let obs = observed.clone();
        h.register_cb(handle.id, move |rev| *obs.borrow_mut() = Some(*rev)).unwrap();
        let rev = h.revoke(handle.id, RevocationReason::PolicyEviction).unwrap();
        // drained: revocation time is not before the in-flight copy end
        assert!(rev.at >= ev.end, "rev.at={} ev.end={}", rev.at, ev.end);
        // invalidated before callback: handle no longer live inside cb's view
        assert!(!h.is_live(handle.id));
        assert_eq!(observed.borrow().unwrap().handle.id, handle.id);
        assert_eq!(observed.borrow().unwrap().reason, RevocationReason::PolicyEviction);
    }

    #[test]
    fn callback_fires_exactly_once() {
        let mut h = rt();
        let handle = h.alloc(MIB, hints(0)).unwrap();
        let fired = Rc::new(RefCell::new(0));
        let f2 = fired.clone();
        h.register_cb(handle.id, move |_| *f2.borrow_mut() += 1).unwrap();
        assert!(h.revoke(handle.id, RevocationReason::TenantPressure).is_some());
        assert!(h.revoke(handle.id, RevocationReason::TenantPressure).is_none());
        assert_eq!(*fired.borrow(), 1);
    }

    #[test]
    fn tenant_pressure_triggers_revocation_on_advance() {
        let mut h = rt();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000_000, 79 * GIB)]),
        );
        let a = h.alloc(2 * GIB, hints(0)).unwrap();
        let b = h.alloc(1 * GIB, hints(0)).unwrap();
        assert_eq!(h.live_bytes_on(1), 3 * GIB);
        let revs = h.advance_to(2_000_000);
        // budget after pressure: 1 GiB; LIFO kills b (1 GiB) -> 2 GiB still
        // over, kills a too.
        assert_eq!(revs.len(), 2);
        assert_eq!(revs[0].handle.id, b.id, "LIFO victim first");
        assert_eq!(revs[1].handle.id, a.id);
        assert!(revs.iter().all(|r| r.reason == RevocationReason::TenantPressure));
        assert_eq!(h.live_bytes_on(1), 0);
    }

    #[test]
    fn partial_pressure_revokes_minimum() {
        let mut h = rt();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000, 78 * GIB)]),
        );
        let a = h.alloc(1 * GIB, hints(0)).unwrap();
        let _b = h.alloc(1 * GIB, hints(0)).unwrap();
        // budget 2 GiB -> both fit exactly; no revocation
        let revs = h.advance_to(2_000);
        assert!(revs.is_empty(), "{revs:?}");
        assert!(h.is_live(a.id));
    }

    #[test]
    fn victim_policy_fifo_and_largest() {
        let mk = |vp| {
            let node = SimNode::new(NodeSpec::h100x2());
            let mut cfg = HarvestConfig::for_node(2);
            cfg.victim_policy = vp;
            let mut h = HarvestRuntime::new(node, cfg);
            let a = h.alloc(3 * GIB, hints(0)).unwrap();
            let b = h.alloc(1 * GIB, hints(0)).unwrap();
            let c = h.alloc(2 * GIB, hints(0)).unwrap();
            h.node.set_tenant_load(
                1,
                TenantLoad::from_steps(80 * GIB, vec![(0, 0), (10, 75 * GIB)]),
            );
            let revs = h.advance_to(20);
            (a, b, c, revs)
        };
        let (a, _b, _c, revs) = mk(VictimPolicy::Fifo);
        assert_eq!(revs[0].handle.id, a.id);
        let (a2, _b2, _c2, revs) = mk(VictimPolicy::LargestFirst);
        assert_eq!(revs[0].handle.id, a2.id, "3 GiB is largest");
        let (_a3, b3, _c3, revs) = mk(VictimPolicy::SmallestFirst);
        assert_eq!(revs[0].handle.id, b3.id, "1 GiB is smallest");
    }

    #[test]
    fn mig_partition_caps_allocation() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.mig[1] = MigConfig::CachePartition { bytes: 1 * GIB };
        let mut h = HarvestRuntime::new(node, cfg);
        let _a = h.alloc(512 * MIB, hints(0)).unwrap();
        let _b = h.alloc(512 * MIB, hints(0)).unwrap();
        assert!(matches!(h.alloc(512 * MIB, hints(0)), Err(HarvestError::NoCapacity { .. })));
    }

    #[test]
    fn mig_p2p_restricted_blocks_device() {
        let node = SimNode::new(NodeSpec::nvlink_domain(3));
        let mut cfg = HarvestConfig::for_node(3);
        cfg.mig[1] = MigConfig::P2pRestricted;
        let mut h = HarvestRuntime::new(node, cfg);
        // gpu1 is restricted; only gpu2 can serve
        let handle = h.alloc(MIB, hints(0)).unwrap();
        assert_eq!(handle.peer, 2);
        let bad = AllocHints { prefer_peer: Some(1), ..hints(0) };
        assert!(matches!(h.alloc(MIB, bad), Err(HarvestError::PeerUnavailable { peer: 1 })));
    }

    #[test]
    fn mig_shrink_revokes_via_enforce() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.mig[1] = MigConfig::CachePartition { bytes: 4 * GIB };
        let mut h = HarvestRuntime::new(node, cfg);
        let _a = h.alloc(3 * GIB, hints(0)).unwrap();
        // operator shrinks the partition
        h.config.mig[1] = MigConfig::CachePartition { bytes: 1 * GIB };
        let revs = h.enforce_pressure();
        assert_eq!(revs.len(), 1);
        assert_eq!(h.live_bytes_on(1), 0);
    }

    #[test]
    fn revoke_peer_clears_everything() {
        let mut h = rt();
        let _a = h.alloc(MIB, hints(0)).unwrap();
        let _b = h.alloc(MIB, hints(0)).unwrap();
        let revs = h.revoke_peer(1, RevocationReason::ExternalReclaim);
        assert_eq!(revs.len(), 2);
        assert_eq!(h.live_bytes_on(1), 0);
        assert!(revs.iter().all(|r| r.reason == RevocationReason::ExternalReclaim));
    }

    #[test]
    fn fetch_to_moves_bytes_over_nvlink() {
        let mut h = rt();
        let handle = h.alloc(64 * MIB, hints(0)).unwrap();
        h.copy_in(handle.id, DeviceId::Host).unwrap();
        let ev = h.fetch_to(handle.id, 0).unwrap();
        assert_eq!(ev.src, DeviceId::Gpu(1));
        assert_eq!(ev.dst, DeviceId::Gpu(0));
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Gpu(1), DeviceId::Gpu(0)), 64 * MIB);
    }

    #[test]
    fn reserve_headroom_respected() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.reserve_bytes = 70 * GIB;
        let mut h = HarvestRuntime::new(node, cfg);
        let _a = h.alloc(9 * GIB, hints(0)).unwrap();
        // 80 - 0 tenant - 70 reserve = 10 GiB budget; 9 fits, next 2 doesn't
        // at alloc time the views don't model reserve, but enforcement does:
        let revs = h.enforce_pressure();
        assert!(revs.is_empty());
        let _b = h.alloc(5 * GIB, hints(0)).unwrap();
        let revs = h.enforce_pressure();
        assert_eq!(revs.len(), 1, "over reserve budget -> revoke LIFO victim");
    }
}
