//! The Harvest controller: allocation, data movement, pressure watching,
//! and the ordered revocation pipeline (§3.2).
//!
//! Lifecycle of a cached object, lease edition:
//!
//! 1. A consumer opens a [`super::session::HarvestSession`] and calls
//!    `alloc` / `alloc_many` — the controller builds peer views, asks
//!    the [`PlacementPolicy`] for a peer (once per call, even for a
//!    vectored batch), allocates in that peer's HBM arena and returns
//!    RAII [`super::session::Lease`]s.
//! 2. The application moves data explicitly through the
//!    [`super::session::Transfer`] builder (`cudaMemcpyPeerAsync`
//!    stand-ins tagged with the lease id).
//! 3. On revocation (tenant pressure, MIG reclaim, policy eviction) the
//!    controller **first drains in-flight DMA touching the region, then
//!    invalidates the placement entry, then enqueues the event** on the
//!    owning session's [`RevocationQueue`] — exactly the §3.2 ordering,
//!    now observable: by the time `drain_revocations` returns an event,
//!    steps 1–2 are guaranteed complete.
//!
//! Leases dropped without release land in a reclaim inbox the controller
//! sweeps at allocation / pressure / time boundaries, so leaked leases
//! cannot leak `bytes_on` accounting. The paper's raw C-style surface
//! (`alloc` → `HarvestHandle`, `free`, `register_cb`, `copy_in`,
//! `fetch_to`) remains as deprecated shims over the same internals.
//!
//! The controller never tracks dirty state and never writes back: a
//! lease's [`Durability`] only tells the *application* what fallback is
//! legal.

use super::api::{
    AllocHints, HarvestError, HarvestHandle, LeaseId, Revocation, RevocationReason,
};
use super::events::{PayloadKind, RevocationEvent, RevocationQueue};
use super::mig::MigConfig;
use super::monitor::PeerMonitor;
use super::policy::{BestFit, PlacementPolicy, PlacementRequest};
use super::session::{HarvestSession, ReclaimInbox, SessionId};
use crate::memsim::{CopyEvent, DeviceId, Ns, SimNode};
use std::collections::BTreeMap;

/// Which live allocations die first under pressure.
// serde is not in the offline crate set; the derive activates once a
// vendored copy is added behind the `serde` feature.
#[cfg_attr(feature = "serde", derive(serde::Deserialize))]
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VictimPolicy {
    /// Newest first (default: oldest entries have proven useful).
    #[default]
    Lifo,
    /// Oldest first.
    Fifo,
    /// Largest first (frees the most with the fewest events).
    LargestFirst,
    /// Smallest first.
    SmallestFirst,
}

impl VictimPolicy {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "lifo" => Ok(VictimPolicy::Lifo),
            "fifo" => Ok(VictimPolicy::Fifo),
            "largest" | "largest-first" => Ok(VictimPolicy::LargestFirst),
            "smallest" | "smallest-first" => Ok(VictimPolicy::SmallestFirst),
            other => anyhow::bail!("unknown victim policy `{other}`"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            VictimPolicy::Lifo => "lifo",
            VictimPolicy::Fifo => "fifo",
            VictimPolicy::LargestFirst => "largest",
            VictimPolicy::SmallestFirst => "smallest",
        }
    }
}

/// Controller configuration.
#[cfg_attr(feature = "serde", derive(serde::Deserialize))]
#[derive(Debug, Clone)]
pub struct HarvestConfig {
    pub victim_policy: VictimPolicy,
    /// Per-GPU MIG partitioning (defaults to disabled everywhere).
    pub mig: Vec<MigConfig>,
    /// Sliding window for churn/bandwidth monitoring.
    pub monitor_window: Ns,
    /// Headroom kept free for tenants on every peer: the controller
    /// revokes once tenant usage pushes free space under this reserve.
    pub reserve_bytes: u64,
}

const GIB: u64 = 1 << 30;

impl HarvestConfig {
    pub fn for_node(n_gpus: usize) -> Self {
        Self {
            victim_policy: VictimPolicy::default(),
            mig: vec![MigConfig::Disabled; n_gpus],
            monitor_window: 1_000_000_000,
            reserve_bytes: 0,
        }
    }

    /// Load from TOML-subset text (see [`crate::config::TomlDoc`] for
    /// the grammar), so node/policy sweeps in benches and `main.rs`
    /// scenarios stop hand-constructing configs. Flat keys:
    ///
    /// ```toml
    /// gpus = 4                 # node size (one MigConfig per GPU), default 2
    /// victim_policy = "lifo"   # lifo | fifo | largest | smallest
    /// reserve_gib = 2          # tenant headroom per peer
    /// monitor_window_ns = 1000000000
    /// mig_cache_gib = 10       # optional: partition every GPU
    /// ```
    ///
    /// Unknown keys are rejected so typos fail loudly.
    pub fn from_toml_str(text: &str) -> anyhow::Result<Self> {
        use anyhow::Context;
        let doc = crate::config::TomlDoc::parse(text)?;
        const KNOWN: &[&str] =
            &["gpus", "victim_policy", "reserve_gib", "monitor_window_ns", "mig_cache_gib"];
        for key in doc.keys() {
            if !KNOWN.contains(&key) {
                anyhow::bail!("unknown harvest config key `{key}`");
            }
        }
        let n_gpus = match doc.get("gpus") {
            Some(v) => v.as_u64().context("key `gpus`")? as usize,
            None => 2,
        };
        if n_gpus < 2 {
            anyhow::bail!("gpus must be >= 2 (need at least one peer)");
        }
        let mut cfg = Self::for_node(n_gpus);
        if let Some(v) = doc.get("victim_policy") {
            cfg.victim_policy = VictimPolicy::parse(v.as_str().context("key `victim_policy`")?)?;
        }
        if let Some(v) = doc.get("reserve_gib") {
            cfg.reserve_bytes = v.as_u64().context("key `reserve_gib`")? * GIB;
        }
        if let Some(v) = doc.get("monitor_window_ns") {
            cfg.monitor_window = v.as_u64().context("key `monitor_window_ns`")?;
        }
        if let Some(v) = doc.get("mig_cache_gib") {
            let bytes = v.as_u64().context("key `mig_cache_gib`")? * GIB;
            for m in &mut cfg.mig {
                *m = MigConfig::CachePartition { bytes };
            }
        }
        Ok(cfg)
    }
}

type Callback = Box<dyn FnMut(&Revocation)>;

/// Per-lease runtime record: the raw placement plus owner routing.
struct LiveEntry {
    handle: HarvestHandle,
    session: SessionId,
    kind: PayloadKind,
}

/// Per-session runtime state.
struct SessionState {
    kind: PayloadKind,
    queue: RevocationQueue,
}

/// The session deprecated shims allocate under (created at construction,
/// so raw-handle call sites need no setup).
const LEGACY_SESSION: SessionId = SessionId(0);

/// The runtime. Owns the simulated node; subsystems (MoE rebalancer, KV
/// manager) drive it single-threadedly through their sessions.
pub struct HarvestRuntime {
    pub node: SimNode,
    policy: Box<dyn PlacementPolicy>,
    pub config: HarvestConfig,
    monitor: PeerMonitor,
    live: BTreeMap<LeaseId, LiveEntry>,
    /// Incremental accounting: our live bytes per peer, and per
    /// (peer, client) for the fairness ledger — avoids an O(live)
    /// scan on every allocation (EXPERIMENTS.md §Perf).
    bytes_on: Vec<u64>,
    client_bytes: BTreeMap<(usize, u32), u64>,
    /// Allocation order per peer (for LIFO/FIFO victim selection):
    /// insertion-sequence -> lease, O(log n) removal on free/revoke.
    order: Vec<BTreeMap<u64, LeaseId>>,
    order_key: BTreeMap<LeaseId, u64>,
    next_order: u64,
    /// Deprecated push-callback registry (shim surface only).
    callbacks: BTreeMap<LeaseId, Callback>,
    next_lease: u64,
    sessions: Vec<SessionState>,
    /// Drop-inbox shared with RAII leases; swept at allocation /
    /// pressure / time boundaries.
    reclaim: ReclaimInbox,
    /// Leases reclaimed by the leak sweep (metrics / tests).
    pub leaked_reclaimed: u64,
    /// Every completed revocation, in order (for tests/metrics).
    pub revocations: Vec<Revocation>,
    /// Cumulative counters.
    pub alloc_attempts: u64,
    pub alloc_failures: u64,
}

impl HarvestRuntime {
    pub fn new(node: SimNode, config: HarvestConfig) -> Self {
        Self::with_policy(node, config, Box::new(BestFit))
    }

    pub fn with_policy(
        node: SimNode,
        config: HarvestConfig,
        policy: Box<dyn PlacementPolicy>,
    ) -> Self {
        assert_eq!(config.mig.len(), node.n_gpus(), "one MigConfig per GPU");
        let n = node.n_gpus();
        let monitor = PeerMonitor::new(n, config.monitor_window);
        Self {
            node,
            policy,
            config,
            monitor,
            live: BTreeMap::new(),
            bytes_on: vec![0; n],
            client_bytes: BTreeMap::new(),
            order: vec![BTreeMap::new(); n],
            order_key: BTreeMap::new(),
            next_order: 0,
            callbacks: BTreeMap::new(),
            next_lease: 0,
            sessions: vec![SessionState { kind: PayloadKind::Generic, queue: RevocationQueue::new() }],
            reclaim: ReclaimInbox::default(),
            leaked_reclaimed: 0,
            revocations: Vec::new(),
            alloc_attempts: 0,
            alloc_failures: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn live_handles(&self) -> impl Iterator<Item = &HarvestHandle> {
        self.live.values().map(|e| &e.handle)
    }

    pub fn live_bytes_on(&self, peer: usize) -> u64 {
        self.bytes_on[peer]
    }

    pub fn is_live(&self, id: LeaseId) -> bool {
        self.live.contains_key(&id)
    }

    /// Raw placement record for a live lease (used by the transfer
    /// builder and metrics).
    pub fn handle_info(&self, id: LeaseId) -> Option<HarvestHandle> {
        self.live.get(&id).map(|e| e.handle)
    }

    // -- session plumbing -------------------------------------------------

    /// Open a session (sugar: [`HarvestSession::open`]).
    pub fn open_session(&mut self, kind: PayloadKind) -> HarvestSession {
        HarvestSession::open(self, kind)
    }

    pub(crate) fn register_session(&mut self, kind: PayloadKind) -> SessionId {
        let id = SessionId(self.sessions.len() as u32);
        self.sessions.push(SessionState { kind, queue: RevocationQueue::new() });
        id
    }

    /// Identity of this runtime instance, stamped onto sessions so that
    /// a session cached from one runtime cannot silently address another
    /// (lease ids and session ids are runtime-local). Derived from the
    /// reclaim inbox's allocation, which lives exactly as long as the
    /// runtime.
    pub(crate) fn runtime_tag(&self) -> usize {
        std::rc::Rc::as_ptr(&self.reclaim) as *const () as usize
    }

    pub(crate) fn reclaim_inbox(&self) -> ReclaimInbox {
        std::rc::Rc::clone(&self.reclaim)
    }

    pub(crate) fn drain_session(&mut self, session: SessionId) -> Vec<RevocationEvent> {
        self.sweep_leaked();
        self.sessions[session.0 as usize].queue.drain()
    }

    pub(crate) fn session_queue_len(&self, session: SessionId) -> usize {
        self.sessions[session.0 as usize].queue.len()
    }

    pub(crate) fn record_peer_transfer(&mut self, peer: usize, at: Ns, bytes: u64) {
        self.monitor.record_transfer(peer, at, bytes);
    }

    pub(crate) fn record_peer_prefetch(&mut self, peer: usize, at: Ns, bytes: u64) {
        self.monitor.record_prefetch_transfer(peer, at, bytes);
    }

    /// Read-only view of the peer monitor (demand vs prefetch bandwidth
    /// attribution, churn windows) for metrics and tests.
    pub fn monitor(&self) -> &PeerMonitor {
        &self.monitor
    }

    /// Free every lease that was dropped without an explicit release.
    /// Returns how many were reclaimed. Called automatically at
    /// allocation, pressure-enforcement, drain and time-advance
    /// boundaries, and callable directly.
    pub fn sweep_leaked(&mut self) -> usize {
        let dropped: Vec<LeaseId> = std::mem::take(&mut *self.reclaim.borrow_mut());
        let mut n = 0;
        for id in dropped {
            // Ids of already-revoked / already-released leases show up
            // here too (their RAII owners were dropped later); skip them.
            if self.live.contains_key(&id) && self.free(id).is_ok() {
                self.leaked_reclaimed += 1;
                n += 1;
            }
        }
        n
    }

    // -- views + accounting ----------------------------------------------

    fn partition_limits(&self) -> Vec<Option<u64>> {
        self.config.mig.iter().map(|m| m.harvest_limit()).collect()
    }

    fn views_for(&mut self, client: Option<u32>) -> Vec<super::monitor::PeerView> {
        self.monitor.observe(&self.node);
        let limits = self.partition_limits();
        let ours: Vec<u64> = (0..self.node.n_gpus())
            .map(|p| match client {
                None => self.bytes_on[p],
                Some(c) => self.client_bytes.get(&(p, c)).copied().unwrap_or(0),
            })
            .collect();
        self.monitor.views(&self.node, &limits, &ours)
    }

    /// Bookkeeping shared by alloc and the two removal paths.
    fn account_add(&mut self, h: &HarvestHandle) {
        self.bytes_on[h.peer] += h.size;
        if let Some(c) = h.client {
            *self.client_bytes.entry((h.peer, c)).or_insert(0) += h.size;
        }
    }

    fn account_remove(&mut self, h: &HarvestHandle) {
        self.bytes_on[h.peer] -= h.size;
        if let Some(c) = h.client {
            if let Some(b) = self.client_bytes.get_mut(&(h.peer, c)) {
                *b -= h.size;
                if *b == 0 {
                    self.client_bytes.remove(&(h.peer, c));
                }
            }
        }
    }

    // -- allocation -------------------------------------------------------

    /// Select a peer for `total` bytes needing `contiguous`-byte
    /// segments, honouring pins. One policy consultation.
    fn select_peer(
        &mut self,
        total: u64,
        contiguous: u64,
        hints: AllocHints,
    ) -> Result<usize, HarvestError> {
        let views = self.views_for(hints.client);
        if let Some(p) = hints.prefer_peer {
            let ok = p < views.len()
                && views[p].harvestable >= total
                && views[p].largest_free >= contiguous
                && Some(p) != hints.compute_gpu
                && self.config.mig[p].allows_harvest();
            if !ok {
                return Err(HarvestError::PeerUnavailable { peer: p });
            }
            return Ok(p);
        }
        // Filter P2P-restricted devices before the policy sees them.
        let views: Vec<_> = views
            .into_iter()
            .filter(|v| self.config.mig[v.device].allows_harvest())
            .collect();
        let req = PlacementRequest {
            size: total,
            contiguous,
            hints,
            views: &views,
            topo: &self.node.topo,
        };
        self.policy.select(&req).ok_or(HarvestError::NoCapacity { requested: total })
    }

    /// Record an arena allocation as a live lease.
    fn admit(
        &mut self,
        session: SessionId,
        peer: usize,
        alloc: crate::memsim::AllocId,
        size: u64,
        hints: AllocHints,
    ) -> HarvestHandle {
        let offset = self.node.gpus[peer].hbm.offset_of(alloc).unwrap();
        let handle = HarvestHandle {
            id: LeaseId(self.next_lease),
            peer,
            alloc,
            offset,
            size,
            durability: hints.durability,
            client: hints.client,
        };
        self.next_lease += 1;
        let kind = self.sessions[session.0 as usize].kind;
        self.live.insert(handle.id, LiveEntry { handle, session, kind });
        self.account_add(&handle);
        let k = self.next_order;
        self.next_order += 1;
        self.order[peer].insert(k, handle.id);
        self.order_key.insert(handle.id, k);
        handle
    }

    /// Single allocation under `session` (the lease wrapper lives in
    /// [`super::session`]).
    pub(crate) fn alloc_raw(
        &mut self,
        session: SessionId,
        size: u64,
        hints: AllocHints,
    ) -> Result<HarvestHandle, HarvestError> {
        self.sweep_leaked();
        self.alloc_attempts += 1;
        if size == 0 {
            self.alloc_failures += 1;
            return Err(HarvestError::ZeroSize);
        }
        let peer = match self.select_peer(size, size, hints) {
            Ok(p) => p,
            Err(e) => {
                self.alloc_failures += 1;
                return Err(e);
            }
        };
        let alloc = self.node.gpus[peer].hbm.alloc(size).map_err(|_| {
            self.alloc_failures += 1;
            HarvestError::NoCapacity { requested: size }
        })?;
        Ok(self.admit(session, peer, alloc, size, hints))
    }

    /// Vectored allocation under `session`: one policy consultation for
    /// the aggregate, one peer for the whole batch, all-or-nothing
    /// (partial arena failure rolls back every element).
    pub(crate) fn alloc_many_raw(
        &mut self,
        session: SessionId,
        sizes: &[u64],
        hints: AllocHints,
    ) -> Result<Vec<HarvestHandle>, HarvestError> {
        self.sweep_leaked();
        if sizes.is_empty() {
            return Ok(Vec::new());
        }
        self.alloc_attempts += sizes.len() as u64;
        let fail = |this: &mut Self, err: HarvestError| {
            this.alloc_failures += sizes.len() as u64;
            Err(err)
        };
        if sizes.contains(&0) {
            return fail(self, HarvestError::ZeroSize);
        }
        let total: u64 = sizes.iter().sum();
        let contiguous = *sizes.iter().max().unwrap();
        let peer = match self.select_peer(total, contiguous, hints) {
            Ok(p) => p,
            Err(e) => return fail(self, e),
        };
        // The views promise `total` bytes of budget and one
        // `contiguous`-size segment; fragmentation can still defeat the
        // batch, so place each element and roll back on the first miss.
        let mut placed = Vec::with_capacity(sizes.len());
        for &size in sizes {
            match self.node.gpus[peer].hbm.alloc(size) {
                Ok(a) => placed.push((a, size)),
                Err(_) => {
                    for (a, _) in placed {
                        self.node.gpus[peer].hbm.free(a);
                    }
                    return fail(self, HarvestError::NoCapacity { requested: total });
                }
            }
        }
        Ok(placed
            .into_iter()
            .map(|(alloc, size)| self.admit(session, peer, alloc, size, hints))
            .collect())
    }

    // -- removal ----------------------------------------------------------

    /// Ordered deallocation (drains lease-tagged DMA first; produces no
    /// revocation event — the owner initiated the free). Prefer
    /// [`HarvestSession::release`], which consumes the RAII lease; this
    /// raw form backs it and the deprecated `harvest_free` shim.
    pub fn free(&mut self, id: LeaseId) -> Result<(), HarvestError> {
        let entry = self.live.remove(&id).ok_or(HarvestError::StaleLease(id))?;
        let handle = entry.handle;
        self.account_remove(&handle);
        self.node.dma.drain_tag(&self.node.topo, id.0);
        self.node.gpus[handle.peer].hbm.free(handle.alloc);
        if let Some(k) = self.order_key.remove(&id) {
            self.order[handle.peer].remove(&k);
        }
        self.callbacks.remove(&id);
        Ok(())
    }

    /// The revocation pipeline for one lease. Ordering per §3.2:
    /// drain in-flight DMA → free + invalidate → make the event
    /// observable (enqueue; fire the deprecated callback if one exists).
    pub fn revoke(&mut self, id: LeaseId, reason: RevocationReason) -> Option<Revocation> {
        let entry = self.live.remove(&id)?;
        let handle = entry.handle;
        self.account_remove(&handle);
        // 1. Drain: advance virtual time past every op touching the region.
        let drained_at = self.node.dma.drain_tag(&self.node.topo, id.0);
        // 2. Invalidate + free.
        self.node.gpus[handle.peer].hbm.free(handle.alloc);
        if let Some(k) = self.order_key.remove(&id) {
            self.order[handle.peer].remove(&k);
        }
        let rev = Revocation { handle, reason, at: drained_at };
        self.revocations.push(rev);
        // 3. Notify. Real sessions get a pull-model event; the legacy
        //    shim session is excluded — nothing can drain its queue, so
        //    enqueueing there would leak one event per revocation (shim
        //    users are notified through `register_cb` below, exactly as
        //    the paper's API was).
        if entry.session != LEGACY_SESSION {
            self.sessions[entry.session.0 as usize].queue.push(RevocationEvent {
                lease: id,
                kind: entry.kind,
                peer: handle.peer,
                size: handle.size,
                durability: handle.durability,
                client: handle.client,
                reason,
                at: drained_at,
            });
        }
        // Fire the deprecated push callback exactly once, if any.
        if let Some(mut cb) = self.callbacks.remove(&id) {
            cb(&rev);
        }
        Some(rev)
    }

    /// Revoke everything on `peer` (e.g. MIG instance reclaimed).
    pub fn revoke_peer(&mut self, peer: usize, reason: RevocationReason) -> Vec<Revocation> {
        let ids: Vec<LeaseId> = self.order[peer].values().copied().collect();
        ids.into_iter().rev().filter_map(|id| self.revoke(id, reason)).collect()
    }

    fn pick_victim(&self, peer: usize) -> Option<LeaseId> {
        let order = &self.order[peer];
        match self.config.victim_policy {
            VictimPolicy::Lifo => order.last_key_value().map(|(_, &id)| id),
            VictimPolicy::Fifo => order.first_key_value().map(|(_, &id)| id),
            VictimPolicy::LargestFirst => {
                order.values().max_by_key(|id| self.live[id].handle.size).copied()
            }
            VictimPolicy::SmallestFirst => {
                order.values().min_by_key(|id| self.live[id].handle.size).copied()
            }
        }
    }

    /// Enforce capacity on every peer at the current virtual time:
    /// while co-tenant demand + our allocations + reserve exceed
    /// capacity (or a MIG partition shrank), revoke victims. Returns the
    /// revocations performed.
    pub fn enforce_pressure(&mut self) -> Vec<Revocation> {
        self.sweep_leaked();
        let now = self.node.clock.now();
        let mut out = Vec::new();
        for peer in 0..self.node.n_gpus() {
            loop {
                let cap = self.node.gpus[peer].hbm.capacity();
                let tenant = self.node.gpus[peer].tenant.used_at(now);
                let ours = self.node.gpus[peer].hbm.used();
                let budget = cap.saturating_sub(tenant).saturating_sub(self.config.reserve_bytes);
                let limit = self.config.mig[peer].harvest_limit().unwrap_or(u64::MAX);
                if ours <= budget.min(limit) {
                    break;
                }
                let Some(victim) = self.pick_victim(peer) else { break };
                if let Some(rev) = self.revoke(victim, RevocationReason::TenantPressure) {
                    out.push(rev);
                }
            }
        }
        self.monitor.observe(&self.node);
        out
    }

    /// Advance virtual time to `t`, enforcing pressure at every tenant
    /// change in between (so revocations happen when capacity disappears,
    /// not when someone next allocates). Returns all revocations.
    pub fn advance_to(&mut self, t: Ns) -> Vec<Revocation> {
        let mut out = Vec::new();
        loop {
            let now = self.node.clock.now();
            let next_change = self
                .node
                .gpus
                .iter()
                .filter_map(|g| g.tenant.next_change_after(now))
                .map(|e| e.at)
                .min();
            match next_change {
                Some(at) if at <= t => {
                    self.node.clock.advance_to(at);
                    out.extend(self.enforce_pressure());
                }
                _ => break,
            }
        }
        self.node.clock.advance_to(t);
        out.extend(self.enforce_pressure());
        out
    }

    /// Policy views at now (for introspection / examples).
    pub fn peer_views(&mut self) -> Vec<super::monitor::PeerView> {
        self.views_for(None)
    }

    // -- deprecated shim surface ------------------------------------------
    //
    // The paper's §3.2 C-style API. Kept thin so the lease migration is
    // reviewable; new code should open a session instead.

    /// §3.2 `harvest_alloc` returning a raw, manually-freed handle.
    /// Allocates under the runtime's legacy session.
    #[deprecated(note = "open a session: `hr.open_session(kind)` then \
                         `session.alloc(&mut hr, size, hints)` returns an RAII `Lease` \
                         (leaks are swept, double free does not typecheck)")]
    pub fn alloc(&mut self, size: u64, hints: AllocHints) -> Result<HarvestHandle, HarvestError> {
        self.alloc_raw(LEGACY_SESSION, size, hints)
    }

    /// §3.2 `harvest_register_cb`. Push callback fired at step 3 of the
    /// revocation pipeline.
    #[deprecated(note = "pull events instead: `session.drain_revocations(&mut hr)` at a tick \
                         boundary — the drain → invalidate → free pipeline is complete before \
                         an event is observable, and no shared mutable state is needed")]
    pub fn register_cb(
        &mut self,
        id: LeaseId,
        cb: impl FnMut(&Revocation) + 'static,
    ) -> Result<(), HarvestError> {
        if !self.live.contains_key(&id) {
            return Err(HarvestError::StaleLease(id));
        }
        self.callbacks.insert(id, Box::new(cb));
        Ok(())
    }

    /// Populate the peer cache (async copy `size` bytes from `src` into
    /// the allocation).
    #[deprecated(note = "use the unified builder: \
                         `Transfer::new().populate(&lease, src).submit(&mut hr)` — batched, \
                         lease-tagged, and chunkable via `.chunked(bytes)`")]
    pub fn copy_in(&mut self, id: LeaseId, src: DeviceId) -> Result<CopyEvent, HarvestError> {
        let h = self.handle_info(id).ok_or(HarvestError::StaleLease(id))?;
        let ev = self.node.copy(src, DeviceId::Gpu(h.peer), h.size, Some(id.0));
        self.monitor.record_transfer(h.peer, ev.end, h.size);
        Ok(ev)
    }

    /// Serve a cache hit (async peer → compute copy).
    #[deprecated(note = "use the unified builder: \
                         `Transfer::new().fetch(&lease, compute_gpu).submit(&mut hr)` — batched, \
                         lease-tagged, and chunkable via `.chunked(bytes)`")]
    pub fn fetch_to(&mut self, id: LeaseId, compute: usize) -> Result<CopyEvent, HarvestError> {
        let h = self.handle_info(id).ok_or(HarvestError::StaleLease(id))?;
        let ev = self.node.copy(DeviceId::Gpu(h.peer), DeviceId::Gpu(compute), h.size, Some(id.0));
        self.monitor.record_transfer(h.peer, ev.end, h.size);
        Ok(ev)
    }
}

#[cfg(test)]
// The shim surface is deliberately exercised here to keep its behavior
// pinned until removal.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::harvest::session::Transfer;
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::NodeSpec;
    use std::cell::RefCell;
    use std::rc::Rc;

    const MIB: u64 = 1 << 20;

    fn rt() -> HarvestRuntime {
        let node = SimNode::new(NodeSpec::h100x2());
        let config = HarvestConfig::for_node(2);
        HarvestRuntime::new(node, config)
    }

    fn hints(compute: usize) -> AllocHints {
        AllocHints { compute_gpu: Some(compute), ..Default::default() }
    }

    #[test]
    fn alloc_places_on_peer_not_compute() {
        let mut h = rt();
        let handle = h.alloc(100 * MIB, hints(0)).unwrap();
        assert_eq!(handle.peer, 1);
        assert_eq!(handle.size, 100 * MIB);
        assert!(h.is_live(handle.id));
        assert_eq!(h.live_bytes_on(1), 100 * MIB);
    }

    #[test]
    fn alloc_respects_tenant_capacity() {
        let mut h = rt();
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 79 * GIB));
        match h.alloc(2 * GIB, hints(0)) {
            Err(HarvestError::NoCapacity { .. }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(h.alloc_failures, 1);
    }

    #[test]
    fn pinned_peer_honoured_or_rejected() {
        let mut h = rt();
        let hint = AllocHints { prefer_peer: Some(1), ..hints(0) };
        let handle = h.alloc(MIB, hint).unwrap();
        assert_eq!(handle.peer, 1);
        // pinning the compute GPU itself is rejected
        let bad = AllocHints { prefer_peer: Some(0), ..hints(0) };
        assert!(matches!(h.alloc(MIB, bad), Err(HarvestError::PeerUnavailable { peer: 0 })));
    }

    #[test]
    fn explicit_free_releases_and_skips_events() {
        let mut h = rt();
        let session = h.open_session(PayloadKind::Generic);
        let lease = session.alloc(&mut h, MIB, hints(0)).unwrap();
        let id = lease.id();
        session.release(&mut h, lease).unwrap();
        assert!(!h.is_live(id));
        assert!(session.drain_revocations(&mut h).is_empty(), "free is not a revocation");
        assert_eq!(h.node.gpus[1].hbm.used(), 0);
        // the raw id is now stale
        assert!(matches!(h.free(id), Err(HarvestError::StaleLease(_))));
    }

    #[test]
    fn revocation_pipeline_completes_before_event_observable() {
        let mut h = rt();
        let session = h.open_session(PayloadKind::Generic);
        let lease = session.alloc(&mut h, 64 * MIB, hints(0)).unwrap();
        let id = lease.id();
        // start a long copy touching the region
        let fill = Transfer::new()
            .populate(&lease, DeviceId::Host)
            .submit(&mut h)
            .unwrap();
        assert!(fill.end > h.node.clock.now(), "copy is in flight");
        let rev = h.revoke(id, RevocationReason::PolicyEviction).unwrap();
        // before draining: the lease is already dead and the bytes freed —
        // invalidation precedes observability
        assert!(!h.is_live(id));
        assert_eq!(h.node.gpus[1].hbm.used(), 0);
        let events = session.drain_revocations(&mut h);
        assert_eq!(events.len(), 1);
        let ev = events[0];
        assert_eq!(ev.lease, id);
        assert_eq!(ev.reason, RevocationReason::PolicyEviction);
        // drained: the event time is not before the in-flight copy end
        assert!(ev.at >= fill.end, "ev.at={} fill.end={}", ev.at, fill.end);
        assert_eq!(ev.at, rev.at);
        // second drain yields nothing: events are delivered exactly once
        assert!(session.drain_revocations(&mut h).is_empty());
        drop(lease); // stale RAII owner; sweep ignores it
        assert_eq!(h.sweep_leaked(), 0);
    }

    #[test]
    fn events_route_to_owning_session() {
        let mut h = rt();
        let kv = h.open_session(PayloadKind::KvBlock);
        let moe = h.open_session(PayloadKind::ExpertWeights);
        let a = kv.alloc(&mut h, MIB, hints(0)).unwrap();
        let b = moe.alloc(&mut h, MIB, hints(0)).unwrap();
        h.revoke_peer(1, RevocationReason::ExternalReclaim);
        let kv_events = kv.drain_revocations(&mut h);
        let moe_events = moe.drain_revocations(&mut h);
        assert_eq!(kv_events.len(), 1);
        assert_eq!(kv_events[0].lease, a.id());
        assert_eq!(kv_events[0].kind, PayloadKind::KvBlock);
        assert_eq!(moe_events.len(), 1);
        assert_eq!(moe_events[0].lease, b.id());
        assert_eq!(moe_events[0].kind, PayloadKind::ExpertWeights);
        drop((a, b));
        h.sweep_leaked();
        assert_eq!(h.live_bytes_on(1), 0);
    }

    #[test]
    fn legacy_callback_shim_fires_exactly_once() {
        let mut h = rt();
        let handle = h.alloc(MIB, hints(0)).unwrap();
        let fired = Rc::new(RefCell::new(0));
        let f2 = fired.clone();
        h.register_cb(handle.id, move |_| *f2.borrow_mut() += 1).unwrap();
        assert!(h.revoke(handle.id, RevocationReason::TenantPressure).is_some());
        assert!(h.revoke(handle.id, RevocationReason::TenantPressure).is_none());
        assert_eq!(*fired.borrow(), 1);
    }

    #[test]
    fn legacy_free_skips_callback() {
        let mut h = rt();
        let handle = h.alloc(MIB, hints(0)).unwrap();
        let fired = Rc::new(RefCell::new(0));
        let f2 = fired.clone();
        h.register_cb(handle.id, move |_| *f2.borrow_mut() += 1).unwrap();
        h.free(handle.id).unwrap();
        assert_eq!(*fired.borrow(), 0, "explicit free must not fire revocation cb");
        assert!(matches!(h.free(handle.id), Err(HarvestError::StaleLease(_))));
    }

    #[test]
    fn tenant_pressure_triggers_revocation_on_advance() {
        let mut h = rt();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000_000, 79 * GIB)]),
        );
        let a = h.alloc(2 * GIB, hints(0)).unwrap();
        let b = h.alloc(1 * GIB, hints(0)).unwrap();
        assert_eq!(h.live_bytes_on(1), 3 * GIB);
        let revs = h.advance_to(2_000_000);
        // budget after pressure: 1 GiB; LIFO kills b (1 GiB) -> 2 GiB still
        // over, kills a too.
        assert_eq!(revs.len(), 2);
        assert_eq!(revs[0].handle.id, b.id, "LIFO victim first");
        assert_eq!(revs[1].handle.id, a.id);
        assert!(revs.iter().all(|r| r.reason == RevocationReason::TenantPressure));
        assert_eq!(h.live_bytes_on(1), 0);
    }

    #[test]
    fn partial_pressure_revokes_minimum() {
        let mut h = rt();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000, 78 * GIB)]),
        );
        let a = h.alloc(1 * GIB, hints(0)).unwrap();
        let _b = h.alloc(1 * GIB, hints(0)).unwrap();
        // budget 2 GiB -> both fit exactly; no revocation
        let revs = h.advance_to(2_000);
        assert!(revs.is_empty(), "{revs:?}");
        assert!(h.is_live(a.id));
    }

    #[test]
    fn victim_policy_fifo_and_largest() {
        let mk = |vp| {
            let node = SimNode::new(NodeSpec::h100x2());
            let mut cfg = HarvestConfig::for_node(2);
            cfg.victim_policy = vp;
            let mut h = HarvestRuntime::new(node, cfg);
            let a = h.alloc(3 * GIB, hints(0)).unwrap();
            let b = h.alloc(1 * GIB, hints(0)).unwrap();
            let c = h.alloc(2 * GIB, hints(0)).unwrap();
            h.node.set_tenant_load(
                1,
                TenantLoad::from_steps(80 * GIB, vec![(0, 0), (10, 75 * GIB)]),
            );
            let revs = h.advance_to(20);
            (a, b, c, revs)
        };
        let (a, _b, _c, revs) = mk(VictimPolicy::Fifo);
        assert_eq!(revs[0].handle.id, a.id);
        let (a2, _b2, _c2, revs) = mk(VictimPolicy::LargestFirst);
        assert_eq!(revs[0].handle.id, a2.id, "3 GiB is largest");
        let (_a3, b3, _c3, revs) = mk(VictimPolicy::SmallestFirst);
        assert_eq!(revs[0].handle.id, b3.id, "1 GiB is smallest");
    }

    #[test]
    fn mig_partition_caps_allocation() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.mig[1] = MigConfig::CachePartition { bytes: 1 * GIB };
        let mut h = HarvestRuntime::new(node, cfg);
        let _a = h.alloc(512 * MIB, hints(0)).unwrap();
        let _b = h.alloc(512 * MIB, hints(0)).unwrap();
        assert!(matches!(h.alloc(512 * MIB, hints(0)), Err(HarvestError::NoCapacity { .. })));
    }

    #[test]
    fn mig_p2p_restricted_blocks_device() {
        let node = SimNode::new(NodeSpec::nvlink_domain(3));
        let mut cfg = HarvestConfig::for_node(3);
        cfg.mig[1] = MigConfig::P2pRestricted;
        let mut h = HarvestRuntime::new(node, cfg);
        // gpu1 is restricted; only gpu2 can serve
        let handle = h.alloc(MIB, hints(0)).unwrap();
        assert_eq!(handle.peer, 2);
        let bad = AllocHints { prefer_peer: Some(1), ..hints(0) };
        assert!(matches!(h.alloc(MIB, bad), Err(HarvestError::PeerUnavailable { peer: 1 })));
    }

    #[test]
    fn mig_shrink_revokes_via_enforce() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.mig[1] = MigConfig::CachePartition { bytes: 4 * GIB };
        let mut h = HarvestRuntime::new(node, cfg);
        let _a = h.alloc(3 * GIB, hints(0)).unwrap();
        // operator shrinks the partition
        h.config.mig[1] = MigConfig::CachePartition { bytes: 1 * GIB };
        let revs = h.enforce_pressure();
        assert_eq!(revs.len(), 1);
        assert_eq!(h.live_bytes_on(1), 0);
    }

    #[test]
    fn revoke_peer_clears_everything() {
        let mut h = rt();
        let _a = h.alloc(MIB, hints(0)).unwrap();
        let _b = h.alloc(MIB, hints(0)).unwrap();
        let revs = h.revoke_peer(1, RevocationReason::ExternalReclaim);
        assert_eq!(revs.len(), 2);
        assert_eq!(h.live_bytes_on(1), 0);
        assert!(revs.iter().all(|r| r.reason == RevocationReason::ExternalReclaim));
    }

    #[test]
    fn fetch_to_moves_bytes_over_nvlink() {
        let mut h = rt();
        let handle = h.alloc(64 * MIB, hints(0)).unwrap();
        h.copy_in(handle.id, DeviceId::Host).unwrap();
        let ev = h.fetch_to(handle.id, 0).unwrap();
        assert_eq!(ev.src, DeviceId::Gpu(1));
        assert_eq!(ev.dst, DeviceId::Gpu(0));
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Gpu(1), DeviceId::Gpu(0)), 64 * MIB);
    }

    #[test]
    fn reserve_headroom_respected() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.reserve_bytes = 70 * GIB;
        let mut h = HarvestRuntime::new(node, cfg);
        let _a = h.alloc(9 * GIB, hints(0)).unwrap();
        // 80 - 0 tenant - 70 reserve = 10 GiB budget; 9 fits, next 2 doesn't
        // at alloc time the views don't model reserve, but enforcement does:
        let revs = h.enforce_pressure();
        assert!(revs.is_empty());
        let _b = h.alloc(5 * GIB, hints(0)).unwrap();
        let revs = h.enforce_pressure();
        assert_eq!(revs.len(), 1, "over reserve budget -> revoke LIFO victim");
    }

    #[test]
    fn config_from_toml_str_parses_and_rejects() {
        let cfg = HarvestConfig::from_toml_str(
            "gpus = 4\nvictim_policy = \"largest\"\nreserve_gib = 2\nmig_cache_gib = 10",
        )
        .unwrap();
        assert_eq!(cfg.mig.len(), 4);
        assert_eq!(cfg.victim_policy, VictimPolicy::LargestFirst);
        assert_eq!(cfg.reserve_bytes, 2 * GIB);
        assert!(cfg.mig.iter().all(|m| m.harvest_limit() == Some(10 * GIB)));
        // defaults
        let cfg = HarvestConfig::from_toml_str("").unwrap();
        assert_eq!(cfg.mig.len(), 2);
        assert_eq!(cfg.victim_policy, VictimPolicy::Lifo);
        // rejections
        assert!(HarvestConfig::from_toml_str("gpus = 1").is_err());
        assert!(HarvestConfig::from_toml_str("victim_policy = \"mru\"").is_err());
        assert!(HarvestConfig::from_toml_str("reserve_gb = 2").is_err(), "typo rejected");
    }

    #[test]
    fn config_from_toml_drives_runtime() {
        let cfg =
            HarvestConfig::from_toml_str("gpus = 2\nvictim_policy = \"fifo\"").unwrap();
        let mut h = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), cfg);
        let a = h.alloc(1 * GIB, hints(0)).unwrap();
        let _b = h.alloc(1 * GIB, hints(0)).unwrap();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (10, 79 * GIB)]),
        );
        let revs = h.advance_to(20);
        assert_eq!(revs[0].handle.id, a.id, "FIFO victim first");
    }
}
