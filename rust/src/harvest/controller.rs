//! The Harvest controller: tier-aware allocation, data movement,
//! pressure watching, and the ordered revocation pipeline (§3.2).
//!
//! Lifecycle of a cached object, tiered-lease edition:
//!
//! 1. A consumer opens a [`super::session::HarvestSession`] and calls
//!    `alloc` / `alloc_many` with a [`TierPreference`] — the controller
//!    builds peer and tier views, asks the [`PlacementPolicy`] for a
//!    tier (once per call, even for a vectored batch), allocates in
//!    that tier's arena (peer HBM, host DRAM, or CXL) and returns RAII
//!    [`super::session::Lease`]s that carry their resident tier.
//! 2. The application moves data explicitly through the
//!    [`super::session::Transfer`] builder (`cudaMemcpyPeerAsync`
//!    stand-ins tagged with the lease id). `Transfer::migrate` moves a
//!    live lease between tiers — demotion and promotion are first-class
//!    operations, not consumer-side copy dances.
//! 3. On revocation (tenant pressure, MIG reclaim, policy eviction) the
//!    controller **first drains in-flight DMA touching the region, then
//!    invalidates the placement entry, then enqueues the event** on the
//!    owning session's [`RevocationQueue`] — exactly the §3.2 ordering,
//!    now observable: by the time `drain_revocations` returns an event,
//!    steps 1–2 are guaranteed complete. Under
//!    [`HarvestConfig::demote_to_host`], pressure-revoked *lossy* leases
//!    are demoted (peer → host migration, lease kept alive) instead of
//!    dropped, surfaced as [`RevocationAction::Demoted`].
//!
//! Leases dropped without release land in a reclaim inbox the controller
//! sweeps at allocation / pressure / time boundaries, so leaked leases
//! cannot leak per-tier `bytes_on` accounting. The paper's raw C-style
//! surface (`alloc` → `HarvestHandle`, `free`, `register_cb`, `copy_in`,
//! `fetch_to`) remains as deprecated shims over the same internals
//! (peer-tier-only, as the paper's API was).
//!
//! The controller never tracks dirty state and never writes back: a
//! lease's [`super::api::Durability`] only tells the *application* what
//! fallback is legal — and gates demotion (host-backed leases are
//! dropped, their host copy already exists; lossy leases are worth
//! moving).

use super::api::{
    AllocHints, HarvestError, HarvestHandle, LeaseId, MemoryTier, Revocation, RevocationReason,
    TierPreference,
};
use super::events::{PayloadKind, RevocationAction, RevocationEvent, RevocationQueue};
use super::mig::MigConfig;
use super::monitor::PeerMonitor;
use super::policy::{BestFit, PlacementPolicy, PlacementRequest, TierView, TieredPlacementRequest};
use super::session::{HarvestSession, ReclaimInbox, SessionId};
use crate::memsim::{CopyEvent, DeviceId, Hbm, Ns, SimNode};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// Which live allocations die first under pressure.
// serde is not in the offline crate set; the derive activates once a
// vendored copy is added behind the `serde` feature.
#[cfg_attr(feature = "serde", derive(serde::Deserialize))]
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VictimPolicy {
    /// Newest first (default: oldest entries have proven useful).
    #[default]
    Lifo,
    /// Oldest first.
    Fifo,
    /// Largest first (frees the most with the fewest events).
    LargestFirst,
    /// Smallest first.
    SmallestFirst,
}

impl VictimPolicy {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "lifo" => Ok(VictimPolicy::Lifo),
            "fifo" => Ok(VictimPolicy::Fifo),
            "largest" | "largest-first" => Ok(VictimPolicy::LargestFirst),
            "smallest" | "smallest-first" => Ok(VictimPolicy::SmallestFirst),
            other => anyhow::bail!("unknown victim policy `{other}`"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            VictimPolicy::Lifo => "lifo",
            VictimPolicy::Fifo => "fifo",
            VictimPolicy::LargestFirst => "largest",
            VictimPolicy::SmallestFirst => "smallest",
        }
    }
}

/// Controller configuration.
#[cfg_attr(feature = "serde", derive(serde::Deserialize))]
#[derive(Debug, Clone)]
pub struct HarvestConfig {
    pub victim_policy: VictimPolicy,
    /// Per-GPU MIG partitioning (defaults to disabled everywhere).
    pub mig: Vec<MigConfig>,
    /// Sliding window for churn/bandwidth monitoring.
    pub monitor_window: Ns,
    /// Headroom kept free for tenants on every peer: the controller
    /// revokes once tenant usage pushes free space under this reserve.
    pub reserve_bytes: u64,
    /// When pressure revokes a *lossy* peer lease, migrate its bytes to
    /// host DRAM (a [`RevocationAction::Demoted`] event; the lease stays
    /// live on the host tier) instead of dropping them. Host-backed
    /// leases are always dropped — their host copy already exists.
    pub demote_to_host: bool,
    /// First rung of the pressure ladder: before demoting (or dropping)
    /// a lossy peer lease, shrink it *in place* to
    /// [`HarvestConfig::compress_ratio_pct`] percent of its size
    /// (modeled layer-wise KV compression — see [`crate::coldtier`]),
    /// surfaced as [`RevocationAction::Compressed`]. The full ladder is
    /// compress → demote → drop.
    pub compress_before_demote: bool,
    /// Target size of an in-place compression, in percent of the
    /// original (1..=99). 50 models fp8-quantize-plus-prune per
    /// PyramidInfer-style layer-wise budgets.
    pub compress_ratio_pct: u32,
    /// Page size of the SSD cold-tier pager ([`crate::coldtier::Pager`]):
    /// every SSD-resident lease occupies whole pages.
    pub ssd_page_bytes: u64,
}

const GIB: u64 = 1 << 30;

impl HarvestConfig {
    pub fn for_node(n_gpus: usize) -> Self {
        Self {
            victim_policy: VictimPolicy::default(),
            mig: vec![MigConfig::Disabled; n_gpus],
            monitor_window: 1_000_000_000,
            reserve_bytes: 0,
            demote_to_host: false,
            compress_before_demote: false,
            compress_ratio_pct: 50,
            ssd_page_bytes: 2 * 1024 * 1024,
        }
    }

    /// Load from TOML-subset text (see [`crate::config::TomlDoc`] for
    /// the grammar), so node/policy sweeps in benches and `main.rs`
    /// scenarios stop hand-constructing configs. Flat keys:
    ///
    /// ```toml
    /// gpus = 4                 # node size (one MigConfig per GPU), default 2
    /// victim_policy = "lifo"   # lifo | fifo | largest | smallest
    /// reserve_gib = 2          # tenant headroom per peer
    /// monitor_window_ns = 1000000000
    /// mig_cache_gib = 10       # optional: partition every GPU
    /// demote_to_host = true    # pressure demotes lossy leases to host
    /// compress_before_demote = true  # ladder: compress -> demote -> drop
    /// compress_ratio_pct = 50  # in-place compression target (1..=99)
    /// ssd_page_kib = 2048      # cold-tier pager page size
    /// ```
    ///
    /// Unknown keys are rejected so typos fail loudly.
    pub fn from_toml_str(text: &str) -> anyhow::Result<Self> {
        use anyhow::Context;
        let doc = crate::config::TomlDoc::parse(text)?;
        const KNOWN: &[&str] = &[
            "gpus",
            "victim_policy",
            "reserve_gib",
            "monitor_window_ns",
            "mig_cache_gib",
            "demote_to_host",
            "compress_before_demote",
            "compress_ratio_pct",
            "ssd_page_kib",
        ];
        for key in doc.keys() {
            if !KNOWN.contains(&key) {
                anyhow::bail!("unknown harvest config key `{key}`");
            }
        }
        let n_gpus = match doc.get("gpus") {
            Some(v) => v.as_u64().context("key `gpus`")? as usize,
            None => 2,
        };
        if n_gpus < 2 {
            anyhow::bail!("gpus must be >= 2 (need at least one peer)");
        }
        let mut cfg = Self::for_node(n_gpus);
        if let Some(v) = doc.get("victim_policy") {
            cfg.victim_policy = VictimPolicy::parse(v.as_str().context("key `victim_policy`")?)?;
        }
        if let Some(v) = doc.get("reserve_gib") {
            cfg.reserve_bytes = v.as_u64().context("key `reserve_gib`")? * GIB;
        }
        if let Some(v) = doc.get("monitor_window_ns") {
            cfg.monitor_window = v.as_u64().context("key `monitor_window_ns`")?;
        }
        if let Some(v) = doc.get("mig_cache_gib") {
            let bytes = v.as_u64().context("key `mig_cache_gib`")? * GIB;
            for m in &mut cfg.mig {
                *m = MigConfig::CachePartition { bytes };
            }
        }
        if let Some(v) = doc.get("demote_to_host") {
            cfg.demote_to_host = v.as_bool().context("key `demote_to_host`")?;
        }
        if let Some(v) = doc.get("compress_before_demote") {
            cfg.compress_before_demote = v.as_bool().context("key `compress_before_demote`")?;
        }
        if let Some(v) = doc.get("compress_ratio_pct") {
            cfg.compress_ratio_pct = v.as_u64().context("key `compress_ratio_pct`")? as u32;
            if cfg.compress_ratio_pct == 0 || cfg.compress_ratio_pct > 99 {
                anyhow::bail!("compress_ratio_pct must be in 1..=99");
            }
        }
        if let Some(v) = doc.get("ssd_page_kib") {
            cfg.ssd_page_bytes = v.as_u64().context("key `ssd_page_kib`")? * 1024;
            if cfg.ssd_page_bytes == 0 {
                anyhow::bail!("ssd_page_kib must be positive");
            }
        }
        Ok(cfg)
    }
}

type Callback = Box<dyn FnMut(&Revocation)>;

/// In-place compression state of a live lease (see
/// [`HarvestRuntime::compression_of`]): set by `Transfer::compress` or
/// the pressure ladder, cleared by `Transfer::decompress`. Consumers
/// charge the modeled decode-side decompression cost
/// ([`crate::coldtier::Compressor::decompress_cost_ns`]) when they next
/// read the payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompressionInfo {
    /// Compressed-to target in percent of the original size.
    pub ratio: u32,
    /// Byte count before compression — what decompression restores.
    pub original_size: u64,
}

/// Per-lease runtime record: the raw placement plus owner routing. The
/// `tier` cell is shared with the consumer's RAII `Lease`, so a
/// migration updates the lease's view of its residency in place.
struct LiveEntry {
    handle: HarvestHandle,
    session: SessionId,
    kind: PayloadKind,
    tier: Rc<Cell<MemoryTier>>,
    /// Present while the lease's bytes are compressed in place.
    compression: Option<CompressionInfo>,
}

/// Per-session runtime state.
struct SessionState {
    kind: PayloadKind,
    queue: RevocationQueue,
}

/// A migration source segment whose in-flight copy still reads it: the
/// ledgers moved to the destination tier at issue time, but the arena
/// segment is only released once virtual time passes the copy's end, so
/// no unrelated allocation can reuse bytes a DMA engine is reading.
struct DeferredFree {
    /// Copy-completion time; the segment is freed at the first
    /// time-advance / allocation boundary at or after it.
    end: Ns,
    tier: MemoryTier,
    alloc: crate::memsim::AllocId,
    bytes: u64,
    /// The owning lease's DMA tag — draining it waits the copy out.
    tag: u64,
}

/// The session deprecated shims allocate under (created at construction,
/// so raw-handle call sites need no setup).
const LEGACY_SESSION: SessionId = SessionId(0);

/// The runtime. Owns the simulated node; subsystems (MoE rebalancer, KV
/// manager) drive it single-threadedly through their sessions.
pub struct HarvestRuntime {
    pub node: SimNode,
    policy: Box<dyn PlacementPolicy>,
    pub config: HarvestConfig,
    monitor: PeerMonitor,
    live: BTreeMap<LeaseId, LiveEntry>,
    /// Incremental accounting: our live bytes per peer GPU plus the two
    /// off-GPU tiers, and per (tier, client) for the fairness ledger —
    /// avoids an O(live) scan on every allocation (EXPERIMENTS.md §Perf).
    bytes_on: Vec<u64>,
    host_bytes_live: u64,
    cxl_bytes_live: u64,
    ssd_bytes_live: u64,
    client_bytes: BTreeMap<(MemoryTier, u32), u64>,
    /// Allocation order per peer (for LIFO/FIFO victim selection):
    /// insertion-sequence -> lease, O(log n) removal on free/revoke.
    /// Host/CXL leases are not victim candidates (no tenant pressure
    /// there) and stay out of these maps.
    order: Vec<BTreeMap<u64, LeaseId>>,
    order_key: BTreeMap<LeaseId, u64>,
    next_order: u64,
    /// Deprecated push-callback registry (shim surface only).
    callbacks: BTreeMap<LeaseId, Callback>,
    next_lease: u64,
    sessions: Vec<SessionState>,
    /// Drop-inbox shared with RAII leases; swept at allocation /
    /// pressure / time boundaries.
    reclaim: ReclaimInbox,
    /// Migration source segments awaiting copy completion before the
    /// arena reuses them, plus per-tier pending-byte rollups (peers by
    /// index, then host, then CXL) so pressure enforcement can subtract
    /// them in O(1).
    deferred: Vec<DeferredFree>,
    pending_free_peer: Vec<u64>,
    pending_free_host: u64,
    pending_free_cxl: u64,
    pending_free_ssd: u64,
    /// Page table + free accounting over the SSD arena: every
    /// SSD-resident lease's segment is page-rounded through it, so
    /// `pager.mapped_bytes() == node.ssd.used()` at every boundary.
    pager: crate::coldtier::Pager,
    /// Leases reclaimed by the leak sweep (metrics / tests).
    pub leaked_reclaimed: u64,
    /// Every completed drop-revocation, in order (for tests/metrics).
    /// Demotions are counted separately — the lease survives them.
    pub revocations: Vec<Revocation>,
    /// Pressure revocations resolved as peer→host demotions.
    pub demotions: u64,
    /// In-place compressions (pressure ladder + consumer-initiated).
    pub compressions: u64,
    /// Completed tier migrations (consumer-initiated + demotions).
    pub migrations: u64,
    /// Cumulative counters.
    pub alloc_attempts: u64,
    pub alloc_failures: u64,
}

impl HarvestRuntime {
    pub fn new(node: SimNode, config: HarvestConfig) -> Self {
        Self::with_policy(node, config, Box::new(BestFit))
    }

    pub fn with_policy(
        node: SimNode,
        config: HarvestConfig,
        policy: Box<dyn PlacementPolicy>,
    ) -> Self {
        assert_eq!(config.mig.len(), node.n_gpus(), "one MigConfig per GPU");
        let n = node.n_gpus();
        let monitor = PeerMonitor::new(n, config.monitor_window);
        let pager = crate::coldtier::Pager::new(config.ssd_page_bytes);
        Self {
            node,
            policy,
            config,
            monitor,
            pager,
            live: BTreeMap::new(),
            bytes_on: vec![0; n],
            host_bytes_live: 0,
            cxl_bytes_live: 0,
            ssd_bytes_live: 0,
            client_bytes: BTreeMap::new(),
            order: vec![BTreeMap::new(); n],
            order_key: BTreeMap::new(),
            next_order: 0,
            callbacks: BTreeMap::new(),
            next_lease: 0,
            sessions: vec![SessionState {
                kind: PayloadKind::Generic,
                queue: RevocationQueue::new(),
            }],
            reclaim: ReclaimInbox::default(),
            deferred: Vec::new(),
            pending_free_peer: vec![0; n],
            pending_free_host: 0,
            pending_free_cxl: 0,
            pending_free_ssd: 0,
            leaked_reclaimed: 0,
            revocations: Vec::new(),
            demotions: 0,
            compressions: 0,
            migrations: 0,
            alloc_attempts: 0,
            alloc_failures: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn live_handles(&self) -> impl Iterator<Item = &HarvestHandle> {
        self.live.values().map(|e| &e.handle)
    }

    /// Our live bytes in peer HBM on GPU `peer`.
    pub fn live_bytes_on(&self, peer: usize) -> u64 {
        self.bytes_on[peer]
    }

    /// Our live bytes on any tier.
    pub fn live_bytes_on_tier(&self, tier: MemoryTier) -> u64 {
        match tier {
            MemoryTier::PeerHbm(g) => self.bytes_on[g],
            MemoryTier::Host => self.host_bytes_live,
            MemoryTier::CxlMem => self.cxl_bytes_live,
            MemoryTier::Ssd => self.ssd_bytes_live,
            MemoryTier::LocalHbm => 0,
        }
    }

    pub fn is_live(&self, id: LeaseId) -> bool {
        self.live.contains_key(&id)
    }

    /// Raw placement record for a live lease (used by the transfer
    /// builder and metrics).
    pub fn handle_info(&self, id: LeaseId) -> Option<HarvestHandle> {
        self.live.get(&id).map(|e| e.handle)
    }

    /// Current resident tier of a live lease.
    pub fn tier_of(&self, id: LeaseId) -> Option<MemoryTier> {
        self.live.get(&id).map(|e| e.handle.tier)
    }

    /// In-place compression state of a live lease: `Some` while its
    /// bytes are compressed (ratio + the byte count decompression
    /// restores), `None` for uncompressed or dead leases.
    pub fn compression_of(&self, id: LeaseId) -> Option<CompressionInfo> {
        self.live.get(&id).and_then(|e| e.compression)
    }

    fn arena(&self, tier: MemoryTier) -> &Hbm {
        match tier {
            MemoryTier::PeerHbm(g) => &self.node.gpus[g].hbm,
            MemoryTier::Host => &self.node.host,
            MemoryTier::CxlMem => &self.node.cxl,
            MemoryTier::Ssd => &self.node.ssd,
            MemoryTier::LocalHbm => unreachable!("local HBM is consumer-managed"),
        }
    }

    fn arena_mut(&mut self, tier: MemoryTier) -> &mut Hbm {
        match tier {
            MemoryTier::PeerHbm(g) => &mut self.node.gpus[g].hbm,
            MemoryTier::Host => &mut self.node.host,
            MemoryTier::CxlMem => &mut self.node.cxl,
            MemoryTier::Ssd => &mut self.node.ssd,
            MemoryTier::LocalHbm => unreachable!("local HBM is consumer-managed"),
        }
    }

    /// Allocate `size` bytes on `tier`'s arena. SSD allocations route
    /// through the cold-tier [`crate::coldtier::Pager`]: the segment is
    /// page-rounded and entered in the page table, so arena occupancy
    /// always equals whole pages.
    fn tier_alloc(
        &mut self,
        tier: MemoryTier,
        size: u64,
    ) -> Result<crate::memsim::AllocId, crate::memsim::AllocError> {
        if tier == MemoryTier::Ssd {
            let padded = self.pager.padded(size);
            let alloc = self.node.ssd.alloc(padded)?;
            self.pager.map(alloc, size);
            Ok(alloc)
        } else {
            self.arena_mut(tier).alloc(size)
        }
    }

    /// Release an arena segment, unmapping it from the pager when it
    /// lives on the SSD tier.
    fn tier_free(&mut self, tier: MemoryTier, alloc: crate::memsim::AllocId) {
        if tier == MemoryTier::Ssd {
            self.pager.unmap(alloc);
        }
        self.arena_mut(tier).free(alloc);
    }

    /// Read-only view of the SSD cold-tier pager (page table + free
    /// accounting) for metrics and invariant checks.
    pub fn pager(&self) -> &crate::coldtier::Pager {
        &self.pager
    }

    // -- session plumbing -------------------------------------------------

    /// Open a session (sugar: [`HarvestSession::open`]).
    pub fn open_session(&mut self, kind: PayloadKind) -> HarvestSession {
        HarvestSession::open(self, kind)
    }

    pub(crate) fn register_session(&mut self, kind: PayloadKind) -> SessionId {
        let id = SessionId(self.sessions.len() as u32);
        self.sessions.push(SessionState { kind, queue: RevocationQueue::new() });
        id
    }

    /// Identity of this runtime instance, stamped onto sessions so that
    /// a session cached from one runtime cannot silently address another
    /// (lease ids and session ids are runtime-local). Derived from the
    /// reclaim inbox's allocation, which lives exactly as long as the
    /// runtime.
    pub(crate) fn runtime_tag(&self) -> usize {
        Rc::as_ptr(&self.reclaim) as *const () as usize
    }

    pub(crate) fn reclaim_inbox(&self) -> ReclaimInbox {
        Rc::clone(&self.reclaim)
    }

    /// The shared residency cell for a live lease (stored on the RAII
    /// `Lease`, updated in place by migrations/demotions).
    pub(crate) fn tier_cell(&self, id: LeaseId) -> Rc<Cell<MemoryTier>> {
        Rc::clone(&self.live.get(&id).expect("live lease").tier)
    }

    pub(crate) fn drain_session(&mut self, session: SessionId) -> Vec<RevocationEvent> {
        self.sweep_leaked();
        self.sessions[session.0 as usize].queue.drain()
    }

    pub(crate) fn session_queue_len(&self, session: SessionId) -> usize {
        self.sessions[session.0 as usize].queue.len()
    }

    /// Attribute a lease-addressed transfer's traffic to its tier slot.
    pub(crate) fn record_tier_traffic(
        &mut self,
        tier: MemoryTier,
        at: Ns,
        bytes: u64,
        background: bool,
    ) {
        if background {
            self.monitor.record_tier_prefetch(tier, at, bytes);
        } else {
            self.monitor.record_tier_transfer(tier, at, bytes);
        }
    }

    /// Read-only view of the peer monitor (demand vs prefetch bandwidth
    /// attribution per tier, churn windows) for metrics and tests.
    pub fn monitor(&self) -> &PeerMonitor {
        &self.monitor
    }

    /// Free every lease that was dropped without an explicit release.
    /// Returns how many were reclaimed. Called automatically at
    /// allocation, pressure-enforcement, drain and time-advance
    /// boundaries, and callable directly.
    pub fn sweep_leaked(&mut self) -> usize {
        self.process_deferred_frees();
        let dropped: Vec<LeaseId> = std::mem::take(&mut *self.reclaim.borrow_mut());
        let mut n = 0;
        for id in dropped {
            // Ids of already-revoked / already-released leases show up
            // here too (their RAII owners were dropped later); skip them.
            if self.live.contains_key(&id) && self.free(id).is_ok() {
                self.leaked_reclaimed += 1;
                n += 1;
            }
        }
        n
    }

    // -- deferred migration-source frees ----------------------------------

    fn pending_slot_mut(&mut self, tier: MemoryTier) -> &mut u64 {
        match tier {
            MemoryTier::PeerHbm(g) => &mut self.pending_free_peer[g],
            MemoryTier::Host => &mut self.pending_free_host,
            MemoryTier::CxlMem => &mut self.pending_free_cxl,
            MemoryTier::Ssd => &mut self.pending_free_ssd,
            MemoryTier::LocalHbm => unreachable!("local HBM is consumer-managed"),
        }
    }

    /// Bytes of migration source segments on `tier` whose copies are
    /// still in flight: already subtracted from the tier ledger
    /// ([`HarvestRuntime::live_bytes_on_tier`]) but still occupying the
    /// arena until virtual time passes each copy's end. The invariant
    /// `arena.used() == ledger + tenant-held + pending frees` holds at
    /// every boundary.
    pub fn pending_free_bytes_on_tier(&self, tier: MemoryTier) -> u64 {
        match tier {
            MemoryTier::PeerHbm(g) => self.pending_free_peer[g],
            MemoryTier::Host => self.pending_free_host,
            MemoryTier::CxlMem => self.pending_free_cxl,
            MemoryTier::Ssd => self.pending_free_ssd,
            MemoryTier::LocalHbm => 0,
        }
    }

    fn defer_source_free(&mut self, handle: &HarvestHandle, end: Ns) {
        *self.pending_slot_mut(handle.tier) += handle.size;
        self.deferred.push(DeferredFree {
            end,
            tier: handle.tier,
            alloc: handle.alloc,
            bytes: handle.size,
            tag: handle.id.0,
        });
    }

    /// Release every deferred segment whose copy has completed by now.
    /// Runs at every allocation / pressure / drain / time-advance
    /// boundary; returns the bytes released.
    fn process_deferred_frees(&mut self) -> u64 {
        let now = self.node.clock.now();
        let mut released = 0;
        let mut i = 0;
        while i < self.deferred.len() {
            if self.deferred[i].end <= now {
                let d = self.deferred.swap_remove(i);
                self.tier_free(d.tier, d.alloc);
                *self.pending_slot_mut(d.tier) -= d.bytes;
                released += d.bytes;
            } else {
                i += 1;
            }
        }
        released
    }

    /// Wait out every in-flight migration copy reading a source segment
    /// on `tier` (advances virtual time — the `cudaStreamSynchronize`
    /// a real allocator stall pays) and release the segments. The
    /// tenant broker tries this before evicting more leases: tenants
    /// always win, even against bytes a demotion is still reading, and
    /// recovering an already-moved source costs no new harvest loss.
    pub fn drain_deferred_frees(&mut self, tier: MemoryTier) -> u64 {
        let tags: Vec<u64> = self
            .deferred
            .iter()
            .filter(|d| d.tier == tier)
            .map(|d| d.tag)
            .collect();
        if tags.is_empty() {
            return 0;
        }
        for tag in tags {
            self.node.dma.drain_tag(&self.node.topo, tag);
        }
        let before = self.pending_free_bytes_on_tier(tier);
        self.process_deferred_frees();
        before - self.pending_free_bytes_on_tier(tier)
    }

    // -- views + accounting ----------------------------------------------

    fn partition_limits(&self) -> Vec<Option<u64>> {
        self.config.mig.iter().map(|m| m.harvest_limit()).collect()
    }

    fn views_for(&mut self, client: Option<u32>) -> Vec<super::monitor::PeerView> {
        self.monitor.observe(&self.node);
        let limits = self.partition_limits();
        let ours: Vec<u64> = (0..self.node.n_gpus())
            .map(|p| match client {
                None => self.bytes_on[p],
                Some(c) => {
                    self.client_bytes.get(&(MemoryTier::PeerHbm(p), c)).copied().unwrap_or(0)
                }
            })
            .collect();
        self.monitor.views(&self.node, &limits, &ours)
    }

    /// Build the cross-tier cost views: one per harvestable peer, plus
    /// host DRAM and (when attached) CXL — but only for tiers the
    /// preference admits; computing cost signals for tiers the policy
    /// may not pick is allocation-hot-path waste. Fetch costs are
    /// estimated against the hinted compute GPU (GPU 0 when unhinted).
    fn tier_views(
        &self,
        peer_views: &[super::monitor::PeerView],
        size: u64,
        hints: &AllocHints,
        pref: TierPreference,
    ) -> Vec<TierView> {
        let reference = hints.compute_gpu.unwrap_or(0);
        let dst = DeviceId::Gpu(reference);
        let now = self.node.clock.now();
        let mut out = Vec::new();
        let mut push = |tier: MemoryTier,
                        free_bytes: u64,
                        largest_free: u64,
                        bw_demand: f64,
                        churn: f64,
                        topo: &crate::memsim::Topology| {
            let src = tier.device();
            let (fetch_ns, peak) = match topo.link_model(src, dst) {
                Some(m) => (m.latency(size), m.peak_bw_bytes_per_ns * 1e9),
                // tier device == reference gpu: a fetch would be local
                None => (0, f64::INFINITY),
            };
            out.push(TierView {
                tier,
                free_bytes,
                largest_free,
                fetch_ns,
                queue_ns: topo.busy_until(src, dst).saturating_sub(now),
                load: (bw_demand / peak).min(4.0),
                churn_per_sec: churn,
            });
        };
        for v in peer_views {
            if !pref.allows(MemoryTier::PeerHbm(v.device)) {
                continue;
            }
            push(
                MemoryTier::PeerHbm(v.device),
                v.harvestable,
                v.largest_free,
                v.bw_demand,
                v.churn_per_sec,
                &self.node.topo,
            );
        }
        if pref.allows(MemoryTier::Host) {
            push(
                MemoryTier::Host,
                self.node.host.free_bytes(),
                self.node.host.largest_free(),
                self.monitor.bw_demand_on_tier(MemoryTier::Host),
                0.0,
                &self.node.topo,
            );
        }
        if self.node.has_cxl() && pref.allows(MemoryTier::CxlMem) {
            push(
                MemoryTier::CxlMem,
                self.node.cxl.free_bytes(),
                self.node.cxl.largest_free(),
                self.monitor.bw_demand_on_tier(MemoryTier::CxlMem),
                0.0,
                &self.node.topo,
            );
        }
        if self.node.has_ssd() && pref.allows(MemoryTier::Ssd) {
            // The SSD hangs off the host bridge with no GPU link, so the
            // generic link lookup above would hit the local-fetch
            // fallback (0 ns, infinite bandwidth) and mis-score the cold
            // tier as free. Compose the staged SSD→host→GPU fetch
            // explicitly: latencies add, queues add, and the NVMe link
            // is the bandwidth bottleneck.
            let topo = &self.node.topo;
            let nvme = topo
                .link_model(DeviceId::Ssd, DeviceId::Host)
                .expect("SSD arena is wired behind the host bridge");
            let pcie = topo.link_model(DeviceId::Host, dst);
            let fetch_ns = nvme.latency(size) + pcie.map_or(0, |m| m.latency(size));
            let queue_ns = topo.busy_until(DeviceId::Ssd, DeviceId::Host).saturating_sub(now)
                + topo.busy_until(DeviceId::Host, dst).saturating_sub(now);
            let peak = nvme.peak_bw_bytes_per_ns * 1e9;
            out.push(TierView {
                tier: MemoryTier::Ssd,
                free_bytes: self.node.ssd.free_bytes(),
                largest_free: self.node.ssd.largest_free(),
                fetch_ns,
                queue_ns,
                load: (self.monitor.bw_demand_on_tier(MemoryTier::Ssd) / peak).min(4.0),
                churn_per_sec: 0.0,
            });
        }
        out
    }

    /// Bookkeeping shared by alloc and the removal/migration paths.
    fn account_add(&mut self, h: &HarvestHandle) {
        match h.tier {
            MemoryTier::PeerHbm(g) => self.bytes_on[g] += h.size,
            MemoryTier::Host => self.host_bytes_live += h.size,
            MemoryTier::CxlMem => self.cxl_bytes_live += h.size,
            MemoryTier::Ssd => self.ssd_bytes_live += h.size,
            MemoryTier::LocalHbm => unreachable!(),
        }
        if let Some(c) = h.client {
            *self.client_bytes.entry((h.tier, c)).or_insert(0) += h.size;
        }
    }

    fn account_remove(&mut self, h: &HarvestHandle) {
        match h.tier {
            MemoryTier::PeerHbm(g) => self.bytes_on[g] -= h.size,
            MemoryTier::Host => self.host_bytes_live -= h.size,
            MemoryTier::CxlMem => self.cxl_bytes_live -= h.size,
            MemoryTier::Ssd => self.ssd_bytes_live -= h.size,
            MemoryTier::LocalHbm => unreachable!(),
        }
        if let Some(c) = h.client {
            if let Some(b) = self.client_bytes.get_mut(&(h.tier, c)) {
                *b -= h.size;
                if *b == 0 {
                    self.client_bytes.remove(&(h.tier, c));
                }
            }
        }
    }

    // -- allocation -------------------------------------------------------

    /// Select a tier for `total` bytes needing `contiguous`-byte
    /// segments, honouring the preference. One policy consultation.
    /// Public so consumers choosing a [`super::session::Transfer::migrate`]
    /// target (e.g. host→peer promotion prefetch) reuse the same policy.
    pub fn select_placement(
        &mut self,
        total: u64,
        contiguous: u64,
        pref: TierPreference,
        hints: AllocHints,
    ) -> Result<MemoryTier, HarvestError> {
        let views = self.views_for(hints.client);
        if let TierPreference::Pinned(t) = pref {
            let ok = match t {
                MemoryTier::PeerHbm(p) => {
                    p < views.len()
                        && views[p].harvestable >= total
                        && views[p].largest_free >= contiguous
                        && Some(p) != hints.compute_gpu
                        && self.config.mig[p].allows_harvest()
                }
                MemoryTier::Host | MemoryTier::CxlMem => {
                    let arena = self.arena(t);
                    arena.free_bytes() >= total && arena.largest_free() >= contiguous
                }
                MemoryTier::Ssd => {
                    // Pager rounding: the arena must hold the
                    // page-padded footprint, not just the logical bytes.
                    let padded = self.pager.padded(total.max(contiguous));
                    let arena = self.arena(t);
                    arena.free_bytes() >= padded && arena.largest_free() >= padded
                }
                MemoryTier::LocalHbm => false,
            };
            return if ok { Ok(t) } else { Err(HarvestError::TierUnavailable { tier: t }) };
        }
        // Filter P2P-restricted devices before the policy sees them.
        let peer_views: Vec<_> = views
            .into_iter()
            .filter(|v| self.config.mig[v.device].allows_harvest())
            .collect();
        let tier_views = self.tier_views(&peer_views, total, &hints, pref);
        let req = TieredPlacementRequest {
            size: total,
            contiguous,
            pref,
            hints,
            peer_views: &peer_views,
            tier_views: &tier_views,
            topo: &self.node.topo,
        };
        self.policy.place_tiered(&req).ok_or(HarvestError::NoCapacity { requested: total })
    }

    /// Record an arena allocation as a live lease.
    fn admit(
        &mut self,
        session: SessionId,
        tier: MemoryTier,
        alloc: crate::memsim::AllocId,
        size: u64,
        hints: AllocHints,
    ) -> HarvestHandle {
        let offset = self.arena(tier).offset_of(alloc).unwrap();
        let handle = HarvestHandle {
            id: LeaseId(self.next_lease),
            tier,
            alloc,
            offset,
            size,
            durability: hints.durability,
            client: hints.client,
        };
        self.next_lease += 1;
        let kind = self.sessions[session.0 as usize].kind;
        self.live.insert(
            handle.id,
            LiveEntry { handle, session, kind, tier: Rc::new(Cell::new(tier)), compression: None },
        );
        self.account_add(&handle);
        if let MemoryTier::PeerHbm(g) = tier {
            let k = self.next_order;
            self.next_order += 1;
            self.order[g].insert(k, handle.id);
            self.order_key.insert(handle.id, k);
        }
        handle
    }

    /// Single allocation under `session` (the lease wrapper lives in
    /// [`super::session`]).
    pub(crate) fn alloc_raw(
        &mut self,
        session: SessionId,
        size: u64,
        pref: TierPreference,
        hints: AllocHints,
    ) -> Result<HarvestHandle, HarvestError> {
        self.sweep_leaked();
        self.alloc_attempts += 1;
        if size == 0 {
            self.alloc_failures += 1;
            return Err(HarvestError::ZeroSize);
        }
        let tier = match self.select_placement(size, size, pref, hints) {
            Ok(t) => t,
            Err(e) => {
                self.alloc_failures += 1;
                return Err(e);
            }
        };
        let alloc = self.tier_alloc(tier, size).map_err(|_| {
            self.alloc_failures += 1;
            HarvestError::NoCapacity { requested: size }
        })?;
        Ok(self.admit(session, tier, alloc, size, hints))
    }

    /// Vectored allocation under `session`: one policy consultation for
    /// the aggregate, one tier for the whole batch, all-or-nothing
    /// (partial arena failure rolls back every element).
    pub(crate) fn alloc_many_raw(
        &mut self,
        session: SessionId,
        sizes: &[u64],
        pref: TierPreference,
        hints: AllocHints,
    ) -> Result<Vec<HarvestHandle>, HarvestError> {
        self.sweep_leaked();
        if sizes.is_empty() {
            return Ok(Vec::new());
        }
        self.alloc_attempts += sizes.len() as u64;
        let fail = |this: &mut Self, err: HarvestError| {
            this.alloc_failures += sizes.len() as u64;
            Err(err)
        };
        if sizes.contains(&0) {
            return fail(self, HarvestError::ZeroSize);
        }
        let total: u64 = sizes.iter().sum();
        let contiguous = *sizes.iter().max().unwrap();
        let tier = match self.select_placement(total, contiguous, pref, hints) {
            Ok(t) => t,
            Err(e) => return fail(self, e),
        };
        // The views promise `total` bytes of budget and one
        // `contiguous`-size segment; fragmentation can still defeat the
        // batch, so place each element and roll back on the first miss.
        let mut placed = Vec::with_capacity(sizes.len());
        for &size in sizes {
            match self.tier_alloc(tier, size) {
                Ok(a) => placed.push((a, size)),
                Err(_) => {
                    for (a, _) in placed {
                        self.tier_free(tier, a);
                    }
                    return fail(self, HarvestError::NoCapacity { requested: total });
                }
            }
        }
        Ok(placed
            .into_iter()
            .map(|(alloc, size)| self.admit(session, tier, alloc, size, hints))
            .collect())
    }

    // -- removal + migration ----------------------------------------------

    /// Ordered deallocation (drains lease-tagged DMA first; produces no
    /// revocation event — the owner initiated the free). Prefer
    /// [`HarvestSession::release`], which consumes the RAII lease; this
    /// raw form backs it and the deprecated `harvest_free` shim.
    pub fn free(&mut self, id: LeaseId) -> Result<(), HarvestError> {
        let entry = self.live.remove(&id).ok_or(HarvestError::StaleLease(id))?;
        let handle = entry.handle;
        self.account_remove(&handle);
        // Draining the lease tag advances time past any migration copy
        // still reading an old source segment of this lease — release
        // whatever that unblocked.
        self.node.dma.drain_tag(&self.node.topo, id.0);
        self.process_deferred_frees();
        self.tier_free(handle.tier, handle.alloc);
        if let Some(k) = self.order_key.remove(&id) {
            if let MemoryTier::PeerHbm(g) = handle.tier {
                self.order[g].remove(&k);
            }
        }
        self.callbacks.remove(&id);
        Ok(())
    }

    /// Phase 1 of a migration: reserve a destination segment for the
    /// lease on tier `to`, without moving anything. The reservation is
    /// pure allocation — rolled back with
    /// [`HarvestRuntime::unprepare_migration`] if a sibling reservation
    /// in the same [`super::session::Transfer`] batch fails, which is
    /// what makes batch submission genuinely all-or-nothing even under
    /// destination fragmentation.
    pub(crate) fn prepare_migration(
        &mut self,
        id: LeaseId,
        to: MemoryTier,
    ) -> Result<crate::memsim::AllocId, HarvestError> {
        let entry = self.live.get(&id).ok_or(HarvestError::StaleLease(id))?;
        // The destination must be migratable at all: never local HBM,
        // never a tier whose arena is absent (CXL on a node without an
        // expander), never a peer the node doesn't have. Link-less
        // pairs (host↔CXL) are fine — commit stages them through the
        // least-loaded GPU-adjacent link.
        let absent = match to {
            MemoryTier::LocalHbm => true,
            MemoryTier::CxlMem => !self.node.has_cxl(),
            MemoryTier::Ssd => !self.node.has_ssd(),
            MemoryTier::PeerHbm(g) => g >= self.node.n_gpus(),
            MemoryTier::Host => false,
        };
        if absent {
            return Err(HarvestError::TierUnavailable { tier: to });
        }
        let size = entry.handle.size;
        self.tier_alloc(to, size).map_err(|_| HarvestError::NoCapacity { requested: size })
    }

    /// Roll back a [`HarvestRuntime::prepare_migration`] reservation.
    pub(crate) fn unprepare_migration(&mut self, to: MemoryTier, alloc: crate::memsim::AllocId) {
        self.tier_free(to, alloc);
    }

    /// Phase 2 of a migration: issue the (lease-tagged) copy into the
    /// reserved segment, release the source, and update the lease's
    /// shared residency cell. The copy is asynchronous — virtual time
    /// does not advance — and the lease tag keeps the §3.2
    /// drain-before-free barrier intact: any later free/revocation of
    /// the lease drains the migration first. A lease already resident on
    /// `to` (e.g. a duplicate migrate in one batch) releases the
    /// reservation and moves nothing. Tier pairs with a direct link
    /// (peer↔host, peer↔CXL) copy straight across; the link-less
    /// host↔CXL pair is staged through the GPU whose adjacent links are
    /// least loaded (two hops, both lease-tagged, the second starting
    /// when the first delivers).
    pub(crate) fn commit_migration(
        &mut self,
        id: LeaseId,
        to: MemoryTier,
        dst_alloc: crate::memsim::AllocId,
        background: bool,
        chunk: Option<u64>,
    ) -> CopyEvent {
        let old = self.live.get(&id).expect("prepared migration names a live lease").handle;
        // An earlier migrate in the same batch may have moved the lease
        // already: a now-redundant hop (same tier) releases its
        // reservation and moves nothing rather than copying from a
        // stale placement.
        if to == old.tier {
            self.tier_free(to, dst_alloc);
            let now = self.node.clock.now();
            return CopyEvent {
                start: now,
                end: now,
                bytes: 0,
                src: old.tier.device(),
                dst: to.device(),
            };
        }
        let (src_dev, dst_dev) = (old.tier.device(), to.device());
        let ev = if self.node.topo.link_model(src_dev, dst_dev).is_some() {
            match chunk {
                Some(c) if old.size > c => self.node.copy_scattered(
                    src_dev,
                    dst_dev,
                    old.size,
                    old.size.div_ceil(c),
                    Some(id.0),
                ),
                _ => self.node.copy(src_dev, dst_dev, old.size, Some(id.0)),
            }
        } else {
            // Link-less pair: stage the copy through intermediate
            // devices. The hops are contiguous — a bounce buffer, not
            // scattered paged descriptors — and all carry the lease tag.
            // GPU↔SSD bounces through host DRAM (the SSD hangs off the
            // host bridge); CXL↔SSD additionally crosses the least-loaded
            // GPU to reach host; host↔CXL bounces through the GPU whose
            // pair of adjacent links is least loaded right now.
            let least_loaded = |node: &SimNode, a: DeviceId, b: DeviceId| {
                (0..node.n_gpus())
                    .min_by_key(|&g| {
                        node.topo.busy_until(a, DeviceId::Gpu(g))
                            + node.topo.busy_until(DeviceId::Gpu(g), b)
                    })
                    .expect("node has at least one GPU")
            };
            match (src_dev, dst_dev) {
                (DeviceId::Gpu(_), DeviceId::Ssd) | (DeviceId::Ssd, DeviceId::Gpu(_)) => {
                    self.node.copy_path(&[src_dev, DeviceId::Host, dst_dev], old.size, Some(id.0))
                }
                (DeviceId::Cxl, DeviceId::Ssd) => {
                    let via = least_loaded(&self.node, DeviceId::Cxl, DeviceId::Host);
                    self.node.copy_path(
                        &[DeviceId::Cxl, DeviceId::Gpu(via), DeviceId::Host, DeviceId::Ssd],
                        old.size,
                        Some(id.0),
                    )
                }
                (DeviceId::Ssd, DeviceId::Cxl) => {
                    let via = least_loaded(&self.node, DeviceId::Host, DeviceId::Cxl);
                    self.node.copy_path(
                        &[DeviceId::Ssd, DeviceId::Host, DeviceId::Gpu(via), DeviceId::Cxl],
                        old.size,
                        Some(id.0),
                    )
                }
                _ => {
                    let via = least_loaded(&self.node, src_dev, dst_dev);
                    self.node.copy_via(src_dev, via, dst_dev, old.size, Some(id.0))
                }
            }
        };
        // Ledgers move at issue time; the *segment* is freed only at
        // copy-completion time (lease-tagged deferred free), so no
        // unrelated allocation can reuse bytes the in-flight copy still
        // reads and per-tier accounting never transiently undercounts
        // the arena. Pressure enforcement subtracts the pending bytes
        // (`pending_free_bytes_on_tier`), so demotions still release
        // peer *budget* immediately and the enforcement loop converges.
        self.defer_source_free(&old, ev.end);
        self.account_remove(&old);
        let offset = self.arena(to).offset_of(dst_alloc).unwrap();
        let entry = self.live.get_mut(&id).unwrap();
        entry.handle.tier = to;
        entry.handle.alloc = dst_alloc;
        entry.handle.offset = offset;
        entry.tier.set(to);
        let new = entry.handle;
        self.account_add(&new);
        // victim-order bookkeeping follows the bytes
        if let Some(k) = self.order_key.remove(&id) {
            if let MemoryTier::PeerHbm(g) = old.tier {
                self.order[g].remove(&k);
            }
        }
        if let MemoryTier::PeerHbm(g) = to {
            let k = self.next_order;
            self.next_order += 1;
            self.order[g].insert(k, id);
            self.order_key.insert(id, k);
        }
        // traffic touches both tiers' links
        self.record_tier_traffic(old.tier, ev.end, old.size, background);
        self.record_tier_traffic(to, ev.end, old.size, background);
        self.migrations += 1;
        ev
    }

    /// One-shot migration (prepare + commit) — the demotion path and
    /// any single-lease consumer use this.
    pub(crate) fn migrate_lease(
        &mut self,
        id: LeaseId,
        to: MemoryTier,
        background: bool,
        chunk: Option<u64>,
    ) -> Result<CopyEvent, HarvestError> {
        if self.tier_of(id).ok_or(HarvestError::StaleLease(id))? == to {
            let now = self.node.clock.now();
            return Ok(CopyEvent { start: now, end: now, bytes: 0, src: to.device(), dst: to.device() });
        }
        let dst_alloc = self.prepare_migration(id, to)?;
        Ok(self.commit_migration(id, to, dst_alloc, background, chunk))
    }

    // -- in-place compression ---------------------------------------------

    /// Shrink a live lease *in place* to `ratio` percent of its current
    /// size (modeled layer-wise KV compression — the freed tail returns
    /// to the arena immediately, which is what makes this rung of the
    /// pressure ladder work even when the arena has zero free headroom).
    /// Compression itself is free in virtual time: the modeled cost is
    /// paid decode-side, when the consumer charges
    /// [`crate::coldtier::Compressor::decompress_cost_ns`] on reload.
    /// An already-compressed lease is left untouched (returns 0).
    /// Returns the bytes released to the arena.
    pub(crate) fn compress_lease(&mut self, id: LeaseId, ratio: u32) -> Result<u64, HarvestError> {
        assert!((1..=99).contains(&ratio), "compress ratio must be in 1..=99, got {ratio}");
        let entry = self.live.get(&id).ok_or(HarvestError::StaleLease(id))?;
        if entry.compression.is_some() {
            return Ok(0);
        }
        let old = entry.handle;
        // Shrinking bytes a DMA engine may still be reading needs the
        // same drain-first ordering as a revocation.
        self.node.dma.drain_tag(&self.node.topo, id.0);
        self.process_deferred_frees();
        let new_size = (old.size * u64::from(ratio) / 100).max(1);
        let released = if old.tier == MemoryTier::Ssd {
            let padded = self.pager.padded(new_size);
            let released = self.node.ssd.shrink(old.alloc, padded);
            self.pager.unmap(old.alloc);
            self.pager.map(old.alloc, new_size);
            released
        } else {
            self.arena_mut(old.tier).shrink(old.alloc, new_size)
        };
        self.account_remove(&old);
        let entry = self.live.get_mut(&id).unwrap();
        entry.handle.size = new_size;
        entry.compression = Some(CompressionInfo { ratio, original_size: old.size });
        let new = entry.handle;
        self.account_add(&new);
        self.compressions += 1;
        Ok(released)
    }

    /// Undo an in-place compression: re-grow the lease to its original
    /// byte count on its current tier (a fresh full-size segment — the
    /// arena must have room, [`HarvestError::NoCapacity`] otherwise) and
    /// clear the compression tag. Returns the bytes restored. A lease
    /// that is not compressed is left untouched (returns 0).
    pub(crate) fn decompress_lease(&mut self, id: LeaseId) -> Result<u64, HarvestError> {
        let entry = self.live.get(&id).ok_or(HarvestError::StaleLease(id))?;
        let Some(info) = entry.compression else { return Ok(0) };
        let old = entry.handle;
        self.node.dma.drain_tag(&self.node.topo, id.0);
        self.process_deferred_frees();
        let new_alloc = self
            .tier_alloc(old.tier, info.original_size)
            .map_err(|_| HarvestError::NoCapacity { requested: info.original_size })?;
        self.tier_free(old.tier, old.alloc);
        let offset = self.arena(old.tier).offset_of(new_alloc).unwrap();
        self.account_remove(&old);
        let entry = self.live.get_mut(&id).unwrap();
        entry.handle.alloc = new_alloc;
        entry.handle.offset = offset;
        entry.handle.size = info.original_size;
        entry.compression = None;
        let new = entry.handle;
        self.account_add(&new);
        Ok(info.original_size - old.size)
    }

    /// The revocation pipeline for one lease. Ordering per §3.2:
    /// drain in-flight DMA → free + invalidate → make the event
    /// observable (enqueue; fire the deprecated callback if one exists).
    pub fn revoke(&mut self, id: LeaseId, reason: RevocationReason) -> Option<Revocation> {
        let entry = self.live.remove(&id)?;
        let handle = entry.handle;
        self.account_remove(&handle);
        // 1. Drain: advance virtual time past every op touching the region.
        let drained_at = self.node.dma.drain_tag(&self.node.topo, id.0);
        self.process_deferred_frees();
        // 2. Invalidate + free.
        self.tier_free(handle.tier, handle.alloc);
        if let Some(k) = self.order_key.remove(&id) {
            if let MemoryTier::PeerHbm(g) = handle.tier {
                self.order[g].remove(&k);
            }
        }
        let rev = Revocation { handle, reason, at: drained_at };
        self.revocations.push(rev);
        // 3. Notify. Real sessions get a pull-model event; the legacy
        //    shim session is excluded — nothing can drain its queue, so
        //    enqueueing there would leak one event per revocation (shim
        //    users are notified through `register_cb` below, exactly as
        //    the paper's API was).
        if entry.session != LEGACY_SESSION {
            self.sessions[entry.session.0 as usize].queue.push(RevocationEvent {
                lease: id,
                kind: entry.kind,
                tier: handle.tier,
                size: handle.size,
                durability: handle.durability,
                client: handle.client,
                reason,
                action: RevocationAction::Dropped,
                at: drained_at,
            });
        }
        // Fire the deprecated push callback exactly once, if any.
        if let Some(mut cb) = self.callbacks.remove(&id) {
            cb(&rev);
        }
        Some(rev)
    }

    /// The compression variant of the revocation pipeline — the first
    /// rung of the compress → demote → drop ladder: shrink a lossy peer
    /// lease in place to [`HarvestConfig::compress_ratio_pct`] percent,
    /// keep it alive on its tier, and surface
    /// [`RevocationAction::Compressed`]. Returns `false` when the lease
    /// is not compressible (not a lossy peer lease, legacy session, or
    /// already compressed) — the caller falls through to demotion, then
    /// drop.
    fn try_compress(&mut self, id: LeaseId, reason: RevocationReason) -> bool {
        let Some(entry) = self.live.get(&id) else { return false };
        let handle = entry.handle;
        let session = entry.session;
        let compressible = handle.tier.is_peer()
            && handle.durability == super::api::Durability::Lossy
            && session != LEGACY_SESSION
            && entry.compression.is_none();
        if !compressible {
            return false;
        }
        // Same §3.2 ordering as a drop: drain in-flight DMA touching the
        // region first, then shrink, then make it observable.
        let drained_at = self.node.dma.drain_tag(&self.node.topo, id.0);
        let ratio = self.config.compress_ratio_pct;
        if self.compress_lease(id, ratio).is_err() {
            return false;
        }
        let kind = self.live.get(&id).map(|e| e.kind).unwrap_or_default();
        self.sessions[session.0 as usize].queue.push(RevocationEvent {
            lease: id,
            kind,
            tier: handle.tier,
            size: handle.size,
            durability: handle.durability,
            client: handle.client,
            reason,
            action: RevocationAction::Compressed { ratio },
            at: drained_at,
        });
        true
    }

    /// The demotion variant of the revocation pipeline: instead of
    /// dropping a lossy peer lease, migrate its bytes to host DRAM and
    /// keep the lease alive there. Returns `false` when the lease is not
    /// demotable (not a lossy peer lease, legacy session, host full) —
    /// the caller falls back to [`HarvestRuntime::revoke`].
    fn try_demote(&mut self, id: LeaseId, reason: RevocationReason) -> bool {
        let Some(entry) = self.live.get(&id) else { return false };
        let handle = entry.handle;
        let session = entry.session;
        let demotable = handle.tier.is_peer()
            && handle.durability == super::api::Durability::Lossy
            && session != LEGACY_SESSION
            && self.node.host.free_bytes() >= handle.size
            && self.node.host.largest_free() >= handle.size;
        if !demotable {
            return false;
        }
        // Same §3.2 ordering as a drop: drain in-flight DMA touching the
        // region first, then move the bytes, then make it observable.
        let drained_at = self.node.dma.drain_tag(&self.node.topo, id.0);
        if self.migrate_lease(id, MemoryTier::Host, false, None).is_err() {
            return false;
        }
        self.demotions += 1;
        let kind = self.live.get(&id).map(|e| e.kind).unwrap_or_default();
        // Stamped with the pipeline-completion (copy-issue) time, like a
        // drop's drained_at — event timestamps stay monotone even when a
        // demotion's async copy lands after a sibling drop.
        self.sessions[session.0 as usize].queue.push(RevocationEvent {
            lease: id,
            kind,
            tier: handle.tier,
            size: handle.size,
            durability: handle.durability,
            client: handle.client,
            reason,
            action: RevocationAction::Demoted { to: MemoryTier::Host },
            at: drained_at,
        });
        true
    }

    /// Revoke everything on `peer` (e.g. MIG instance reclaimed).
    pub fn revoke_peer(&mut self, peer: usize, reason: RevocationReason) -> Vec<Revocation> {
        let ids: Vec<LeaseId> = self.order[peer].values().copied().collect();
        ids.into_iter().rev().filter_map(|id| self.revoke(id, reason)).collect()
    }

    fn pick_victim(&self, peer: usize) -> Option<LeaseId> {
        let order = &self.order[peer];
        match self.config.victim_policy {
            VictimPolicy::Lifo => order.last_key_value().map(|(_, &id)| id),
            VictimPolicy::Fifo => order.first_key_value().map(|(_, &id)| id),
            VictimPolicy::LargestFirst => {
                order.values().max_by_key(|id| self.live[id].handle.size).copied()
            }
            VictimPolicy::SmallestFirst => {
                order.values().min_by_key(|id| self.live[id].handle.size).copied()
            }
        }
    }

    /// Enforce capacity on every peer at the current virtual time:
    /// while co-tenant demand + our allocations + reserve exceed
    /// capacity (or a MIG partition shrank), revoke victims — demoting
    /// lossy ones to host when [`HarvestConfig::demote_to_host`] is on.
    /// Returns the drop-revocations performed (demotions are visible via
    /// [`HarvestRuntime::demotions`] and the session event queues).
    pub fn enforce_pressure(&mut self) -> Vec<Revocation> {
        self.sweep_leaked();
        let now = self.node.clock.now();
        let mut out = Vec::new();
        for peer in 0..self.node.n_gpus() {
            loop {
                let g = &self.node.gpus[peer];
                let cap = g.hbm.capacity();
                // Co-tenants: the exogenous timeline plus actor-held
                // arena segments. Our bytes: everything else in the
                // arena, minus sources of in-flight migrations (their
                // budget already moved to the destination tier).
                let tenant = g.tenant_used_at(now);
                let ours = g
                    .hbm
                    .used()
                    .saturating_sub(g.tenant_held)
                    .saturating_sub(self.pending_free_peer[peer]);
                let budget = cap.saturating_sub(tenant).saturating_sub(self.config.reserve_bytes);
                let limit = self.config.mig[peer].harvest_limit().unwrap_or(u64::MAX);
                if ours <= budget.min(limit) {
                    break;
                }
                let Some(victim) = self.pick_victim(peer) else { break };
                // The ladder: compress in place, then demote, then drop.
                if self.config.compress_before_demote
                    && self.try_compress(victim, RevocationReason::TenantPressure)
                {
                    continue;
                }
                let demoted = self.config.demote_to_host
                    && self.try_demote(victim, RevocationReason::TenantPressure);
                if !demoted {
                    if let Some(rev) = self.revoke(victim, RevocationReason::TenantPressure) {
                        out.push(rev);
                    }
                }
            }
        }
        self.monitor.observe(&self.node);
        out
    }

    /// Make room for a tenant allocation on `peer` by revoking (or,
    /// under [`HarvestConfig::demote_to_host`], demoting) one victim
    /// lease there. Returns `false` when no revocable lease remains on
    /// the peer — the paper's correctness invariant is that tenants
    /// always win, so the [`crate::tenantsim::PressureBroker`] loops
    /// this until the tenant's arena allocation succeeds or harvest
    /// genuinely holds nothing on the GPU.
    pub fn yield_to_tenant(&mut self, peer: usize) -> bool {
        self.sweep_leaked();
        let Some(victim) = self.pick_victim(peer) else { return false };
        if self.config.compress_before_demote
            && self.try_compress(victim, RevocationReason::TenantPressure)
        {
            return true;
        }
        if self.config.demote_to_host && self.try_demote(victim, RevocationReason::TenantPressure)
        {
            return true;
        }
        self.revoke(victim, RevocationReason::TenantPressure);
        true
    }

    /// The host/CXL analogue of [`HarvestRuntime::yield_to_tenant`]:
    /// revoke one live lease resident on `tier` so a tenant's host or
    /// CXL allocation can proceed. Victim choice follows the configured
    /// [`VictimPolicy`] over allocation order (lease ids are monotone).
    pub fn yield_tier_to_tenant(&mut self, tier: MemoryTier) -> bool {
        if tier.is_peer() {
            return self.yield_to_tenant(tier.peer_gpu().expect("peer tier"));
        }
        self.sweep_leaked();
        let on_tier = self.live.iter().filter(|(_, e)| e.handle.tier == tier);
        let victim = match self.config.victim_policy {
            VictimPolicy::Lifo => on_tier.map(|(&id, _)| id).max(),
            VictimPolicy::Fifo => on_tier.map(|(&id, _)| id).min(),
            VictimPolicy::LargestFirst => on_tier
                .max_by_key(|(&id, e)| (e.handle.size, std::cmp::Reverse(id)))
                .map(|(&id, _)| id),
            VictimPolicy::SmallestFirst => {
                on_tier.min_by_key(|(&id, e)| (e.handle.size, id)).map(|(&id, _)| id)
            }
        };
        let Some(victim) = victim else { return false };
        self.revoke(victim, RevocationReason::TenantPressure);
        true
    }

    /// Advance virtual time to `t`, enforcing pressure at every tenant
    /// change in between (so revocations happen when capacity disappears,
    /// not when someone next allocates). Returns all drop-revocations.
    pub fn advance_to(&mut self, t: Ns) -> Vec<Revocation> {
        let mut out = Vec::new();
        loop {
            let now = self.node.clock.now();
            let next_change = self
                .node
                .gpus
                .iter()
                .filter_map(|g| g.tenant.next_change_after(now))
                .map(|e| e.at)
                .min();
            match next_change {
                Some(at) if at <= t => {
                    self.node.clock.advance_to(at);
                    out.extend(self.enforce_pressure());
                }
                _ => break,
            }
        }
        self.node.clock.advance_to(t);
        out.extend(self.enforce_pressure());
        out
    }

    /// Policy views at now (for introspection / examples).
    pub fn peer_views(&mut self) -> Vec<super::monitor::PeerView> {
        self.views_for(None)
    }

    // -- deprecated shim surface ------------------------------------------
    //
    // The paper's §3.2 C-style API: peer-tier-only, raw handles, push
    // callbacks. Kept thin so the lease migration is reviewable; new
    // code should open a session instead.

    /// §3.2 `harvest_alloc` returning a raw, manually-freed handle.
    /// Allocates peer HBM under the runtime's legacy session.
    #[deprecated(note = "open a session: `hr.open_session(kind)` then \
                         `session.alloc(&mut hr, size, pref, hints)` returns an RAII `Lease` \
                         carrying its resident tier (leaks are swept, double free does not \
                         typecheck)")]
    pub fn alloc(&mut self, size: u64, hints: AllocHints) -> Result<HarvestHandle, HarvestError> {
        self.alloc_raw(LEGACY_SESSION, size, TierPreference::PEER_ONLY, hints)
    }

    /// §3.2 `harvest_register_cb`. Push callback fired at step 3 of the
    /// revocation pipeline.
    #[deprecated(note = "pull events instead: `session.drain_revocations(&mut hr)` at a tick \
                         boundary — the drain → invalidate → free pipeline is complete before \
                         an event is observable, and no shared mutable state is needed")]
    pub fn register_cb(
        &mut self,
        id: LeaseId,
        cb: impl FnMut(&Revocation) + 'static,
    ) -> Result<(), HarvestError> {
        if !self.live.contains_key(&id) {
            return Err(HarvestError::StaleLease(id));
        }
        self.callbacks.insert(id, Box::new(cb));
        Ok(())
    }

    /// Populate the cache (async copy `size` bytes from `src` into the
    /// allocation's tier).
    #[deprecated(note = "use the unified builder: \
                         `Transfer::new().populate(&lease, src).submit(&mut hr)` — batched, \
                         lease-tagged, and chunkable via `.chunked(bytes)`")]
    pub fn copy_in(&mut self, id: LeaseId, src: DeviceId) -> Result<CopyEvent, HarvestError> {
        let h = self.handle_info(id).ok_or(HarvestError::StaleLease(id))?;
        let ev = self.node.copy(src, h.tier.device(), h.size, Some(id.0));
        self.record_tier_traffic(h.tier, ev.end, h.size, false);
        Ok(ev)
    }

    /// Serve a cache hit (async tier → compute copy).
    #[deprecated(note = "use the unified builder: \
                         `Transfer::new().fetch(&lease, compute_gpu).submit(&mut hr)` — batched, \
                         lease-tagged, and chunkable via `.chunked(bytes)`")]
    pub fn fetch_to(&mut self, id: LeaseId, compute: usize) -> Result<CopyEvent, HarvestError> {
        let h = self.handle_info(id).ok_or(HarvestError::StaleLease(id))?;
        let ev = self.node.copy(h.tier.device(), DeviceId::Gpu(compute), h.size, Some(id.0));
        self.record_tier_traffic(h.tier, ev.end, h.size, false);
        Ok(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::api::Durability;
    use crate::harvest::session::{Lease, Transfer};
    use crate::memsim::tenant::TenantLoad;
    use crate::memsim::NodeSpec;
    use std::cell::RefCell;
    use std::rc::Rc;

    const MIB: u64 = 1 << 20;

    fn rt() -> HarvestRuntime {
        let node = SimNode::new(NodeSpec::h100x2());
        let config = HarvestConfig::for_node(2);
        HarvestRuntime::new(node, config)
    }

    fn hints(compute: usize) -> AllocHints {
        AllocHints { compute_gpu: Some(compute), ..Default::default() }
    }

    /// Peer-HBM allocation through the supported session surface.
    fn peer_alloc(
        h: &mut HarvestRuntime,
        s: &HarvestSession,
        size: u64,
    ) -> Result<Lease, HarvestError> {
        s.alloc(h, size, TierPreference::PEER_ONLY, hints(0))
    }

    #[test]
    fn alloc_places_on_peer_not_compute() {
        let mut h = rt();
        let s = h.open_session(PayloadKind::Generic);
        let lease = peer_alloc(&mut h, &s, 100 * MIB).unwrap();
        assert_eq!(lease.tier(), MemoryTier::PeerHbm(1));
        assert_eq!(lease.size(), 100 * MIB);
        assert!(h.is_live(lease.id()));
        assert_eq!(h.live_bytes_on(1), 100 * MIB);
        s.release(&mut h, lease).unwrap();
    }

    #[test]
    fn peer_pressure_rejects_or_spills_by_preference() {
        let mut h = rt();
        h.node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 79 * GIB));
        let s = h.open_session(PayloadKind::Generic);
        // peers-only: the paper-era failure
        match peer_alloc(&mut h, &s, 2 * GIB) {
            Err(HarvestError::NoCapacity { .. }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(h.alloc_failures, 1);
        // fastest-available: the tier policy spills to host DRAM instead
        let lease =
            s.alloc(&mut h, 2 * GIB, TierPreference::FastestAvailable, hints(0)).unwrap();
        assert_eq!(lease.tier(), MemoryTier::Host, "peer full -> host tier");
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), 2 * GIB);
        assert_eq!(h.live_bytes_on(1), 0);
        s.release(&mut h, lease).unwrap();
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), 0);
    }

    #[test]
    fn pinned_tier_honoured_or_rejected() {
        let mut h = rt();
        let s = h.open_session(PayloadKind::Generic);
        let lease =
            s.alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::PeerHbm(1)), hints(0))
                .unwrap();
        assert_eq!(lease.tier(), MemoryTier::PeerHbm(1));
        s.release(&mut h, lease).unwrap();
        // pinning the compute GPU itself is rejected
        let err = s
            .alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::PeerHbm(0)), hints(0))
            .unwrap_err();
        assert_eq!(err, HarvestError::TierUnavailable { tier: MemoryTier::PeerHbm(0) });
        // host pin lands in host DRAM even with free peers
        let lease =
            s.alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::Host), hints(0)).unwrap();
        assert_eq!(lease.tier(), MemoryTier::Host);
        s.release(&mut h, lease).unwrap();
        // CXL pin fails on a node without the expander...
        let err = s
            .alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::CxlMem), hints(0))
            .unwrap_err();
        assert_eq!(err, HarvestError::TierUnavailable { tier: MemoryTier::CxlMem });
        // ...and works once one is attached
        let mut h = HarvestRuntime::new(
            SimNode::new(NodeSpec::h100x2().with_cxl(64 * GIB)),
            HarvestConfig::for_node(2),
        );
        let s = h.open_session(PayloadKind::Generic);
        let lease =
            s.alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::CxlMem), hints(0)).unwrap();
        assert_eq!(lease.tier(), MemoryTier::CxlMem);
        assert_eq!(h.live_bytes_on_tier(MemoryTier::CxlMem), MIB);
        s.release(&mut h, lease).unwrap();
    }

    #[test]
    fn explicit_free_releases_and_skips_events() {
        let mut h = rt();
        let session = h.open_session(PayloadKind::Generic);
        let lease = peer_alloc(&mut h, &session, MIB).unwrap();
        let id = lease.id();
        session.release(&mut h, lease).unwrap();
        assert!(!h.is_live(id));
        assert!(session.drain_revocations(&mut h).is_empty(), "free is not a revocation");
        assert_eq!(h.node.gpus[1].hbm.used(), 0);
        // the raw id is now stale
        assert!(matches!(h.free(id), Err(HarvestError::StaleLease(_))));
    }

    #[test]
    fn revocation_pipeline_completes_before_event_observable() {
        let mut h = rt();
        let session = h.open_session(PayloadKind::Generic);
        let lease = peer_alloc(&mut h, &session, 64 * MIB).unwrap();
        let id = lease.id();
        // start a long copy touching the region
        let fill = Transfer::new()
            .populate(&lease, DeviceId::Host)
            .submit(&mut h)
            .unwrap();
        assert!(fill.end > h.node.clock.now(), "copy is in flight");
        let rev = h.revoke(id, RevocationReason::PolicyEviction).unwrap();
        // before draining: the lease is already dead and the bytes freed —
        // invalidation precedes observability
        assert!(!h.is_live(id));
        assert_eq!(h.node.gpus[1].hbm.used(), 0);
        let events = session.drain_revocations(&mut h);
        assert_eq!(events.len(), 1);
        let ev = events[0];
        assert_eq!(ev.lease, id);
        assert_eq!(ev.reason, RevocationReason::PolicyEviction);
        assert_eq!(ev.action, RevocationAction::Dropped);
        assert_eq!(ev.tier, MemoryTier::PeerHbm(1));
        // drained: the event time is not before the in-flight copy end
        assert!(ev.at >= fill.end, "ev.at={} fill.end={}", ev.at, fill.end);
        assert_eq!(ev.at, rev.at);
        // second drain yields nothing: events are delivered exactly once
        assert!(session.drain_revocations(&mut h).is_empty());
        drop(lease); // stale RAII owner; sweep ignores it
        assert_eq!(h.sweep_leaked(), 0);
    }

    #[test]
    fn events_route_to_owning_session() {
        let mut h = rt();
        let kv = h.open_session(PayloadKind::KvBlock);
        let moe = h.open_session(PayloadKind::ExpertWeights);
        let a = peer_alloc(&mut h, &kv, MIB).unwrap();
        let b = peer_alloc(&mut h, &moe, MIB).unwrap();
        h.revoke_peer(1, RevocationReason::ExternalReclaim);
        let kv_events = kv.drain_revocations(&mut h);
        let moe_events = moe.drain_revocations(&mut h);
        assert_eq!(kv_events.len(), 1);
        assert_eq!(kv_events[0].lease, a.id());
        assert_eq!(kv_events[0].kind, PayloadKind::KvBlock);
        assert_eq!(moe_events.len(), 1);
        assert_eq!(moe_events[0].lease, b.id());
        assert_eq!(moe_events[0].kind, PayloadKind::ExpertWeights);
        drop((a, b));
        h.sweep_leaked();
        assert_eq!(h.live_bytes_on(1), 0);
    }

    #[test]
    fn demotion_moves_lossy_lease_to_host_and_keeps_it_alive() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.demote_to_host = true;
        let mut h = HarvestRuntime::new(node, cfg);
        let s = h.open_session(PayloadKind::KvBlock);
        let lossy = s
            .alloc(
                &mut h,
                GIB,
                TierPreference::PEER_ONLY,
                AllocHints { durability: Durability::Lossy, ..hints(0) },
            )
            .unwrap();
        let backed = s
            .alloc(
                &mut h,
                GIB,
                TierPreference::PEER_ONLY,
                AllocHints { durability: Durability::HostBacked, ..hints(0) },
            )
            .unwrap();
        let now = h.node.clock.now();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1_000, 80 * GIB)]),
        );
        let revs = h.advance_to(now + 2_000);
        // the host-backed lease is dropped (its host copy already
        // exists); the lossy one is demoted, not dropped
        assert_eq!(revs.len(), 1, "only the host-backed lease drops");
        assert_eq!(revs[0].handle.id, backed.id());
        assert_eq!(h.demotions, 1);
        assert!(h.is_live(lossy.id()), "demoted lease survives");
        assert_eq!(lossy.tier(), MemoryTier::Host, "shared cell tracks the migration");
        assert_eq!(h.tier_of(lossy.id()), Some(MemoryTier::Host));
        assert_eq!(h.live_bytes_on(1), 0);
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), GIB);
        assert_eq!(h.node.host.used(), GIB);
        // both outcomes observable, with the right actions
        let events = s.drain_revocations(&mut h);
        assert_eq!(events.len(), 2);
        let demoted =
            events.iter().find(|e| e.lease == lossy.id()).expect("demotion event");
        assert_eq!(demoted.action, RevocationAction::Demoted { to: MemoryTier::Host });
        assert_eq!(demoted.tier, MemoryTier::PeerHbm(1), "revoked *from* the peer tier");
        let dropped = events.iter().find(|e| e.lease == backed.id()).unwrap();
        assert_eq!(dropped.action, RevocationAction::Dropped);
        // the demoted lease still fetches (now over PCIe) and releases
        let ev = Transfer::new().fetch(&lossy, 0).submit(&mut h).unwrap();
        assert_eq!(ev.events[0].src, DeviceId::Host);
        s.release(&mut h, lossy).unwrap();
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), 0);
        drop(backed);
        h.sweep_leaked();
    }

    #[test]
    fn host_cxl_migration_stages_through_least_loaded_gpu() {
        let mut h = HarvestRuntime::new(
            SimNode::new(NodeSpec::h100x2().with_cxl(64 * GIB)),
            HarvestConfig::for_node(2),
        );
        let s = h.open_session(PayloadKind::KvBlock);
        let lease =
            s.alloc(&mut h, 8 * MIB, TierPreference::Pinned(MemoryTier::Host), hints(0)).unwrap();
        // Load gpu0's host-adjacent link so the least-loaded choice is gpu1.
        Transfer::new()
            .raw(DeviceId::Host, DeviceId::Gpu(0), 512 * MIB)
            .submit(&mut h)
            .unwrap();
        let report =
            Transfer::new().migrate(&lease, MemoryTier::CxlMem).submit(&mut h).unwrap();
        // Both hops of the staged copy moved the bytes through gpu1.
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Host, DeviceId::Gpu(1)), 8 * MIB);
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Gpu(1), DeviceId::Cxl), 8 * MIB);
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Host, DeviceId::Gpu(0)), 512 * MIB);
        // Accounting follows the bytes at issue time: host ledger empty,
        // CXL holds them. The host *segment* stays pinned (pending
        // free) until the staged copy completes — no early reuse.
        assert_eq!(lease.tier(), MemoryTier::CxlMem);
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), 0);
        assert_eq!(h.live_bytes_on_tier(MemoryTier::CxlMem), 8 * MIB);
        assert_eq!(h.pending_free_bytes_on_tier(MemoryTier::Host), 8 * MIB);
        assert_eq!(h.node.host.used(), 8 * MIB, "source pinned while the copy reads it");
        assert_eq!(h.node.cxl.used(), 8 * MIB);
        assert_eq!(h.migrations, 1);
        // The drain barrier covers both hops: releasing waits out hop 2,
        // which also releases the deferred source segment.
        assert!(report.end > h.node.clock.now(), "staged migration is async");
        s.release(&mut h, lease).unwrap();
        assert!(h.node.clock.now() >= report.end);
        assert_eq!(h.live_bytes_on_tier(MemoryTier::CxlMem), 0);
        assert_eq!(h.pending_free_bytes_on_tier(MemoryTier::Host), 0);
        assert_eq!(h.node.host.used(), 0, "deferred free lands at copy completion");
        // And the reverse direction (CXL -> host) stages too.
        let lease =
            s.alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::CxlMem), hints(0)).unwrap();
        Transfer::new().migrate(&lease, MemoryTier::Host).submit(&mut h).unwrap();
        assert_eq!(lease.tier(), MemoryTier::Host);
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Host), MIB);
        s.release(&mut h, lease).unwrap();
    }

    // The shim surface (the paper's §3.2 C-style API) is deliberately
    // exercised in exactly one place to keep its behavior pinned until
    // removal.
    #[test]
    #[allow(deprecated)]
    fn shim_surface_compat() {
        let mut h = rt();
        // raw alloc lands on the peer tier, never the compute GPU
        let handle = h.alloc(64 * MIB, hints(0)).unwrap();
        assert_eq!(handle.tier, MemoryTier::PeerHbm(1));
        assert_eq!(handle.peer_gpu(), Some(1));
        assert!(h.is_live(handle.id));
        // copy_in + fetch_to still move real bytes over NVLink
        h.copy_in(handle.id, DeviceId::Host).unwrap();
        let ev = h.fetch_to(handle.id, 0).unwrap();
        assert_eq!(ev.src, DeviceId::Gpu(1));
        assert_eq!(ev.dst, DeviceId::Gpu(0));
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Gpu(1), DeviceId::Gpu(0)), 64 * MIB);
        // push callback fires exactly once, on revocation only
        let fired = Rc::new(RefCell::new(0));
        let f2 = fired.clone();
        h.register_cb(handle.id, move |_| *f2.borrow_mut() += 1).unwrap();
        assert!(h.revoke(handle.id, RevocationReason::TenantPressure).is_some());
        assert!(h.revoke(handle.id, RevocationReason::TenantPressure).is_none());
        assert_eq!(*fired.borrow(), 1);
        // explicit free never fires the callback and goes stale after
        let handle = h.alloc(MIB, hints(0)).unwrap();
        let fired = Rc::new(RefCell::new(0));
        let f2 = fired.clone();
        h.register_cb(handle.id, move |_| *f2.borrow_mut() += 1).unwrap();
        h.free(handle.id).unwrap();
        assert_eq!(*fired.borrow(), 0, "explicit free must not fire revocation cb");
        assert!(matches!(h.free(handle.id), Err(HarvestError::StaleLease(_))));
    }

    #[test]
    fn lease_fetch_moves_bytes_over_nvlink() {
        // The shim-era copy_in/fetch_to path, ported to the supported
        // Transfer builder: same bytes over the same links.
        let mut h = rt();
        let s = h.open_session(PayloadKind::Generic);
        let lease = peer_alloc(&mut h, &s, 64 * MIB).unwrap();
        let report = Transfer::new()
            .populate(&lease, DeviceId::Host)
            .fetch(&lease, 0)
            .submit(&mut h)
            .unwrap();
        assert_eq!(report.events[1].src, DeviceId::Gpu(1));
        assert_eq!(report.events[1].dst, DeviceId::Gpu(0));
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Gpu(1), DeviceId::Gpu(0)), 64 * MIB);
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Host, DeviceId::Gpu(1)), 64 * MIB);
        s.release(&mut h, lease).unwrap();
    }

    #[test]
    fn tenant_pressure_triggers_revocation_on_advance() {
        let mut h = rt();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000_000, 79 * GIB)]),
        );
        let s = h.open_session(PayloadKind::Generic);
        let a = peer_alloc(&mut h, &s, 2 * GIB).unwrap();
        let b = peer_alloc(&mut h, &s, GIB).unwrap();
        assert_eq!(h.live_bytes_on(1), 3 * GIB);
        let revs = h.advance_to(2_000_000);
        // budget after pressure: 1 GiB; LIFO kills b (1 GiB) -> 2 GiB still
        // over, kills a too.
        assert_eq!(revs.len(), 2);
        assert_eq!(revs[0].handle.id, b.id(), "LIFO victim first");
        assert_eq!(revs[1].handle.id, a.id());
        assert!(revs.iter().all(|r| r.reason == RevocationReason::TenantPressure));
        assert_eq!(h.live_bytes_on(1), 0);
        drop((a, b));
        h.sweep_leaked();
    }

    #[test]
    fn partial_pressure_revokes_minimum() {
        let mut h = rt();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000, 78 * GIB)]),
        );
        let s = h.open_session(PayloadKind::Generic);
        let a = peer_alloc(&mut h, &s, GIB).unwrap();
        let b = peer_alloc(&mut h, &s, GIB).unwrap();
        // budget 2 GiB -> both fit exactly; no revocation
        let revs = h.advance_to(2_000);
        assert!(revs.is_empty(), "{revs:?}");
        assert!(h.is_live(a.id()));
        s.release(&mut h, a).unwrap();
        s.release(&mut h, b).unwrap();
    }

    #[test]
    fn victim_policy_fifo_and_largest() {
        let mk = |vp| {
            let node = SimNode::new(NodeSpec::h100x2());
            let mut cfg = HarvestConfig::for_node(2);
            cfg.victim_policy = vp;
            let mut h = HarvestRuntime::new(node, cfg);
            let s = h.open_session(PayloadKind::Generic);
            let a = peer_alloc(&mut h, &s, 3 * GIB).unwrap();
            let b = peer_alloc(&mut h, &s, GIB).unwrap();
            let c = peer_alloc(&mut h, &s, 2 * GIB).unwrap();
            h.node.set_tenant_load(
                1,
                TenantLoad::from_steps(80 * GIB, vec![(0, 0), (10, 75 * GIB)]),
            );
            let revs = h.advance_to(20);
            let first = revs[0].handle.id;
            drop((a, b, c));
            h.sweep_leaked();
            first
        };
        // allocation order: a (3 GiB), b (1 GiB), c (2 GiB)
        let mk_ids = |vp| {
            let node = SimNode::new(NodeSpec::h100x2());
            let mut cfg = HarvestConfig::for_node(2);
            cfg.victim_policy = vp;
            let mut h = HarvestRuntime::new(node, cfg);
            let s = h.open_session(PayloadKind::Generic);
            let a = peer_alloc(&mut h, &s, 3 * GIB).unwrap();
            let b = peer_alloc(&mut h, &s, GIB).unwrap();
            let _c = peer_alloc(&mut h, &s, 2 * GIB).unwrap();
            (a.id(), b.id())
        };
        let (a_id, _) = mk_ids(VictimPolicy::Fifo);
        assert_eq!(mk(VictimPolicy::Fifo), a_id, "FIFO kills oldest");
        let (a_id, _) = mk_ids(VictimPolicy::LargestFirst);
        assert_eq!(mk(VictimPolicy::LargestFirst), a_id, "3 GiB is largest");
        let (_, b_id) = mk_ids(VictimPolicy::SmallestFirst);
        assert_eq!(mk(VictimPolicy::SmallestFirst), b_id, "1 GiB is smallest");
    }

    #[test]
    fn mig_partition_caps_allocation() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.mig[1] = MigConfig::CachePartition { bytes: GIB };
        let mut h = HarvestRuntime::new(node, cfg);
        let s = h.open_session(PayloadKind::Generic);
        let a = peer_alloc(&mut h, &s, 512 * MIB).unwrap();
        let b = peer_alloc(&mut h, &s, 512 * MIB).unwrap();
        assert!(matches!(
            peer_alloc(&mut h, &s, 512 * MIB),
            Err(HarvestError::NoCapacity { .. })
        ));
        s.release(&mut h, a).unwrap();
        s.release(&mut h, b).unwrap();
    }

    #[test]
    fn mig_p2p_restricted_blocks_device() {
        let node = SimNode::new(NodeSpec::nvlink_domain(3));
        let mut cfg = HarvestConfig::for_node(3);
        cfg.mig[1] = MigConfig::P2pRestricted;
        let mut h = HarvestRuntime::new(node, cfg);
        let s = h.open_session(PayloadKind::Generic);
        // gpu1 is restricted; only gpu2 can serve
        let lease = peer_alloc(&mut h, &s, MIB).unwrap();
        assert_eq!(lease.tier(), MemoryTier::PeerHbm(2));
        let err = s
            .alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::PeerHbm(1)), hints(0))
            .unwrap_err();
        assert_eq!(err, HarvestError::TierUnavailable { tier: MemoryTier::PeerHbm(1) });
        s.release(&mut h, lease).unwrap();
    }

    #[test]
    fn mig_shrink_revokes_via_enforce() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.mig[1] = MigConfig::CachePartition { bytes: 4 * GIB };
        let mut h = HarvestRuntime::new(node, cfg);
        let s = h.open_session(PayloadKind::Generic);
        let a = peer_alloc(&mut h, &s, 3 * GIB).unwrap();
        // operator shrinks the partition
        h.config.mig[1] = MigConfig::CachePartition { bytes: GIB };
        let revs = h.enforce_pressure();
        assert_eq!(revs.len(), 1);
        assert_eq!(h.live_bytes_on(1), 0);
        drop(a);
        h.sweep_leaked();
    }

    #[test]
    fn revoke_peer_clears_everything() {
        let mut h = rt();
        let s = h.open_session(PayloadKind::Generic);
        let a = peer_alloc(&mut h, &s, MIB).unwrap();
        let b = peer_alloc(&mut h, &s, MIB).unwrap();
        let revs = h.revoke_peer(1, RevocationReason::ExternalReclaim);
        assert_eq!(revs.len(), 2);
        assert_eq!(h.live_bytes_on(1), 0);
        assert!(revs.iter().all(|r| r.reason == RevocationReason::ExternalReclaim));
        drop((a, b));
        h.sweep_leaked();
    }

    #[test]
    fn reserve_headroom_respected() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.reserve_bytes = 70 * GIB;
        let mut h = HarvestRuntime::new(node, cfg);
        let s = h.open_session(PayloadKind::Generic);
        let a = peer_alloc(&mut h, &s, 9 * GIB).unwrap();
        // 80 - 0 tenant - 70 reserve = 10 GiB budget; 9 fits, next 5 doesn't
        // at alloc time the views don't model reserve, but enforcement does:
        let revs = h.enforce_pressure();
        assert!(revs.is_empty());
        let b = peer_alloc(&mut h, &s, 5 * GIB).unwrap();
        let revs = h.enforce_pressure();
        assert_eq!(revs.len(), 1, "over reserve budget -> revoke LIFO victim");
        drop((a, b));
        h.sweep_leaked();
    }

    #[test]
    fn ssd_pin_is_page_rounded_and_pager_balances() {
        let mut h = HarvestRuntime::new(
            SimNode::new(NodeSpec::h100x2().with_ssd(64 * GIB)),
            HarvestConfig::for_node(2),
        );
        let page = h.config.ssd_page_bytes;
        let s = h.open_session(PayloadKind::KvBlock);
        // 3 MiB rounds up to two 2 MiB pages in the arena
        let lease = s
            .alloc(&mut h, 3 * MIB, TierPreference::Pinned(MemoryTier::Ssd), hints(0))
            .unwrap();
        assert_eq!(lease.tier(), MemoryTier::Ssd);
        assert_eq!(lease.size(), 3 * MIB, "logical size is unrounded");
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Ssd), 3 * MIB);
        assert_eq!(h.node.ssd.used(), 4 * MIB, "arena occupancy is whole pages");
        assert_eq!(h.pager().pages_mapped(), 2);
        assert_eq!(h.pager().mapped_bytes(), h.node.ssd.used());
        assert_eq!(h.pager().page_bytes(), page);
        s.release(&mut h, lease).unwrap();
        assert_eq!(h.node.ssd.used(), 0);
        assert_eq!(h.pager().pages_mapped(), 0);
        // a node without an SSD arena rejects the pin
        let mut h = rt();
        let s = h.open_session(PayloadKind::KvBlock);
        let err = s
            .alloc(&mut h, MIB, TierPreference::Pinned(MemoryTier::Ssd), hints(0))
            .unwrap_err();
        assert_eq!(err, HarvestError::TierUnavailable { tier: MemoryTier::Ssd });
    }

    #[test]
    fn migrate_stages_gpu_to_ssd_through_host() {
        let mut h = HarvestRuntime::new(
            SimNode::new(NodeSpec::h100x2().with_ssd(64 * GIB)),
            HarvestConfig::for_node(2),
        );
        let s = h.open_session(PayloadKind::KvBlock);
        let lease = peer_alloc(&mut h, &s, 8 * MIB).unwrap();
        Transfer::new().migrate(&lease, MemoryTier::Ssd).submit(&mut h).unwrap();
        assert_eq!(lease.tier(), MemoryTier::Ssd);
        // both hops of the staged write-back moved the bytes
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Gpu(1), DeviceId::Host), 8 * MIB);
        assert_eq!(h.node.topo.bytes_moved(DeviceId::Host, DeviceId::Ssd), 8 * MIB);
        assert_eq!(h.live_bytes_on(1), 0);
        assert_eq!(h.live_bytes_on_tier(MemoryTier::Ssd), 8 * MIB);
        assert_eq!(h.pager().mapped_bytes(), h.node.ssd.used());
        // promote back up: SSD → host → peer, lease intact throughout
        Transfer::new().migrate(&lease, MemoryTier::PeerHbm(1)).submit(&mut h).unwrap();
        assert_eq!(lease.tier(), MemoryTier::PeerHbm(1));
        assert_eq!(h.live_bytes_on(1), 8 * MIB);
        s.release(&mut h, lease).unwrap();
        assert_eq!(h.node.ssd.used(), 0, "deferred SSD free lands after the drain");
        assert_eq!(h.pager().pages_mapped(), 0);
    }

    #[test]
    fn pressure_ladder_compresses_then_demotes() {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.demote_to_host = true;
        cfg.compress_before_demote = true;
        cfg.compress_ratio_pct = 50;
        let mut h = HarvestRuntime::new(node, cfg);
        let s = h.open_session(PayloadKind::KvBlock);
        let lossy = s
            .alloc(
                &mut h,
                2 * GIB,
                TierPreference::PEER_ONLY,
                AllocHints { durability: Durability::Lossy, ..hints(0) },
            )
            .unwrap();
        // Mild pressure: compressing to 1 GiB is enough, so the first
        // rung of the ladder resolves it in place.
        let now = h.node.clock.now();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1_000, 79 * GIB - GIB / 2)]),
        );
        let revs = h.advance_to(now + 2_000);
        assert!(revs.is_empty(), "nothing dropped: {revs:?}");
        assert_eq!(h.compressions, 1);
        assert_eq!(h.demotions, 0);
        assert!(h.is_live(lossy.id()));
        assert_eq!(lossy.tier(), MemoryTier::PeerHbm(1), "compressed in place");
        assert_eq!(h.live_bytes_on(1), GIB, "half the bytes remain");
        let info = h.compression_of(lossy.id()).expect("compressed");
        assert_eq!(info, CompressionInfo { ratio: 50, original_size: 2 * GIB });
        let events = s.drain_revocations(&mut h);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].action, RevocationAction::Compressed { ratio: 50 });
        assert_eq!(events[0].size, 2 * GIB, "event reports the pre-compression size");
        // Tighter pressure: the lease is already compressed, so the next
        // rung demotes it to host — still alive, still compressed.
        let now = h.node.clock.now();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1_000, 80 * GIB)]),
        );
        let revs = h.advance_to(now + 2_000);
        assert!(revs.is_empty(), "demoted, not dropped: {revs:?}");
        assert_eq!(h.demotions, 1);
        assert_eq!(lossy.tier(), MemoryTier::Host);
        assert!(h.compression_of(lossy.id()).is_some(), "compression survives demotion");
        let events = s.drain_revocations(&mut h);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].action, RevocationAction::Demoted { to: MemoryTier::Host });
        s.release(&mut h, lossy).unwrap();
    }

    #[test]
    fn compress_decompress_round_trip_restores_bytes() {
        let mut h = rt();
        let s = h.open_session(PayloadKind::KvBlock);
        let lease = peer_alloc(&mut h, &s, 64 * MIB).unwrap();
        let released = h.compress_lease(lease.id(), 25).unwrap();
        assert_eq!(released, 48 * MIB);
        assert_eq!(h.live_bytes_on(1), 16 * MIB);
        assert_eq!(h.node.gpus[1].hbm.used(), 16 * MIB);
        // double compression is a no-op, not a recompress
        assert_eq!(h.compress_lease(lease.id(), 25).unwrap(), 0);
        assert_eq!(h.compressions, 1);
        let restored = h.decompress_lease(lease.id()).unwrap();
        assert_eq!(restored, 48 * MIB);
        assert!(h.compression_of(lease.id()).is_none());
        assert_eq!(h.live_bytes_on(1), 64 * MIB);
        assert_eq!(h.node.gpus[1].hbm.used(), 64 * MIB);
        // decompressing an uncompressed lease is a no-op
        assert_eq!(h.decompress_lease(lease.id()).unwrap(), 0);
        s.release(&mut h, lease).unwrap();
    }

    #[test]
    fn config_from_toml_str_parses_and_rejects() {
        let cfg = HarvestConfig::from_toml_str(
            "gpus = 4\nvictim_policy = \"largest\"\nreserve_gib = 2\nmig_cache_gib = 10\n\
             demote_to_host = true",
        )
        .unwrap();
        assert_eq!(cfg.mig.len(), 4);
        assert_eq!(cfg.victim_policy, VictimPolicy::LargestFirst);
        assert_eq!(cfg.reserve_bytes, 2 * GIB);
        assert!(cfg.demote_to_host);
        assert!(cfg.mig.iter().all(|m| m.harvest_limit() == Some(10 * GIB)));
        // defaults
        let cfg = HarvestConfig::from_toml_str("").unwrap();
        assert_eq!(cfg.mig.len(), 2);
        assert_eq!(cfg.victim_policy, VictimPolicy::Lifo);
        assert!(!cfg.demote_to_host);
        // rejections
        assert!(HarvestConfig::from_toml_str("gpus = 1").is_err());
        assert!(HarvestConfig::from_toml_str("victim_policy = \"mru\"").is_err());
        assert!(HarvestConfig::from_toml_str("reserve_gb = 2").is_err(), "typo rejected");
        assert!(HarvestConfig::from_toml_str("demote_to_host = 3").is_err(), "bool only");
    }

    #[test]
    fn config_from_toml_drives_runtime() {
        let cfg =
            HarvestConfig::from_toml_str("gpus = 2\nvictim_policy = \"fifo\"").unwrap();
        let mut h = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), cfg);
        let s = h.open_session(PayloadKind::Generic);
        let a = peer_alloc(&mut h, &s, GIB).unwrap();
        let b = peer_alloc(&mut h, &s, GIB).unwrap();
        h.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (10, 79 * GIB)]),
        );
        let revs = h.advance_to(20);
        assert_eq!(revs[0].handle.id, a.id(), "FIFO victim first");
        drop((a, b));
        h.sweep_leaked();
    }
}
