//! Placement policies (§3.2 "Allocation policy").
//!
//! A [`PlacementPolicy`] chooses which peer GPU serves a `harvest_alloc`.
//! The paper's prototype uses best-fit; the section explicitly sketches
//! four alternatives ("Other policies can optimize locality ..., fairness
//! ..., interference ..., or stability ...") — all five are implemented
//! here and ablated in `rust/benches/` (DESIGN.md experiment index).

use super::api::AllocHints;
use super::monitor::PeerView;
use crate::memsim::Topology;

/// Context a policy sees for one allocation request. A vectored
/// `alloc_many` batch is presented as a single request: `size` is the
/// aggregate and `contiguous` the largest single element, so the policy
/// is consulted once per batch rather than once per element.
pub struct PlacementRequest<'a> {
    /// Total bytes requested (whole batch for vectored allocations).
    pub size: u64,
    /// Largest single element — the segment size the arena must be able
    /// to carve contiguously (== `size` for scalar allocations).
    pub contiguous: u64,
    pub hints: AllocHints,
    pub views: &'a [PeerView],
    pub topo: &'a Topology,
}

impl PlacementRequest<'_> {
    /// Peers that can serve the request at all (not the compute GPU,
    /// enough budget for the total, a fitting segment for the largest
    /// element).
    pub fn feasible(&self) -> impl Iterator<Item = &PeerView> + '_ {
        self.views.iter().filter(move |v| {
            Some(v.device) != self.hints.compute_gpu
                && v.harvestable >= self.size
                && v.largest_free >= self.contiguous
        })
    }
}

/// Chooses a peer GPU for an allocation, or `None` to reject.
pub trait PlacementPolicy {
    fn name(&self) -> &'static str;
    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize>;
}

/// The paper's default: the feasible peer whose fitting segment leaves
/// the least leftover (minimises fragmentation). Ties break to the lower
/// device index for determinism.
#[derive(Debug, Default, Clone)]
pub struct BestFit;

impl PlacementPolicy for BestFit {
    fn name(&self) -> &'static str {
        "best-fit"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible()
            .min_by_key(|v| (v.largest_free - req.contiguous, v.device))
            .map(|v| v.device)
    }
}

/// Simplest baseline: first feasible peer by index.
#[derive(Debug, Default, Clone)]
pub struct FirstAvailable;

impl PlacementPolicy for FirstAvailable {
    fn name(&self) -> &'static str {
        "first-available"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible().map(|v| v.device).next()
    }
}

/// Locality: prefer the peer with the lowest estimated fetch latency to
/// the compute GPU (NVLink-adjacent peers first on multi-hop fabrics).
#[derive(Debug, Default, Clone)]
pub struct LocalityAware;

impl PlacementPolicy for LocalityAware {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        let compute = req.hints.compute_gpu?;
        req.feasible()
            .filter_map(|v| {
                let lat = req.topo.estimate(
                    crate::memsim::DeviceId::Gpu(v.device),
                    crate::memsim::DeviceId::Gpu(compute),
                    req.size,
                )?;
                Some((lat, v.device))
            })
            .min()
            .map(|(_, d)| d)
    }
}

/// Fairness: rate-limit individual clients to `per_client_cap` bytes per
/// peer; among feasible peers pick the one where this client holds the
/// least.
#[derive(Debug, Clone)]
pub struct RateLimitFairness {
    pub per_client_cap: u64,
}

impl PlacementPolicy for RateLimitFairness {
    fn name(&self) -> &'static str {
        "fairness"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible()
            .filter(|v| v.our_bytes + req.size <= self.per_client_cap)
            .min_by_key(|v| (v.our_bytes, v.device))
            .map(|v| v.device)
    }
}

/// Interference: avoid peers whose links already move a lot of data.
#[derive(Debug, Clone)]
pub struct InterferenceAware {
    /// Peers above this bytes/sec demand are considered hot.
    pub bw_demand_ceiling: f64,
}

impl Default for InterferenceAware {
    fn default() -> Self {
        Self { bw_demand_ceiling: 100e9 } // 100 GB/s
    }
}

impl PlacementPolicy for InterferenceAware {
    fn name(&self) -> &'static str {
        "interference"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        let cool =
            req.feasible().filter(|v| v.bw_demand < self.bw_demand_ceiling).min_by(|a, b| {
                a.bw_demand.partial_cmp(&b.bw_demand).unwrap().then(a.device.cmp(&b.device))
            });
        cool.map(|v| v.device)
            // All peers hot: fall back to the least-hot feasible one.
            .or_else(|| {
                req.feasible()
                    .min_by(|a, b| a.bw_demand.partial_cmp(&b.bw_demand).unwrap())
                    .map(|v| v.device)
            })
    }
}

/// Stability: prefer peers with low tenant churn (fewer future
/// revocations).
#[derive(Debug, Default, Clone)]
pub struct StabilityAware;

impl PlacementPolicy for StabilityAware {
    fn name(&self) -> &'static str {
        "stability"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible()
            .min_by(|a, b| {
                a.churn_per_sec
                    .partial_cmp(&b.churn_per_sec)
                    .unwrap()
                    .then(a.device.cmp(&b.device))
            })
            .map(|v| v.device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{Clock, Topology};

    fn view(device: usize, harvestable: u64, largest: u64) -> PeerView {
        PeerView {
            device,
            harvestable,
            largest_free: largest,
            churn_per_sec: 0.0,
            bw_demand: 0.0,
            our_bytes: 0,
        }
    }

    fn topo(n: usize) -> Topology {
        Topology::h100_node(Clock::new(), n)
    }

    fn req<'a>(size: u64, hints: AllocHints, views: &'a [PeerView], topo: &'a Topology)
        -> PlacementRequest<'a> {
        PlacementRequest { size, contiguous: size, hints, views, topo }
    }

    #[test]
    fn best_fit_minimises_leftover() {
        let t = topo(4);
        let views =
            vec![view(0, 1000, 1000), view(1, 500, 500), view(2, 300, 300), view(3, 100, 100)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(250, hints, &views, &t);
        assert_eq!(BestFit.select(&r), Some(2), "300-byte segment leaves least");
    }

    #[test]
    fn compute_gpu_never_selected() {
        let t = topo(2);
        let views = vec![view(0, 1000, 1000), view(1, 10, 10)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(100, hints, &views, &t);
        assert_eq!(BestFit.select(&r), None, "only feasible peer is the compute GPU itself");
    }

    #[test]
    fn infeasible_when_fragmented() {
        let t = topo(2);
        // plenty harvestable but no contiguous segment
        let views = vec![view(0, 0, 0), view(1, 1000, 50)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(100, hints, &views, &t);
        assert_eq!(BestFit.select(&r), None);
    }

    #[test]
    fn first_available_picks_lowest_index() {
        let t = topo(3);
        let views = vec![view(0, 0, 0), view(1, 500, 500), view(2, 500, 500)];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(FirstAvailable.select(&r), Some(1));
    }

    #[test]
    fn locality_needs_compute_hint() {
        let t = topo(3);
        let views = vec![view(1, 500, 500), view(2, 500, 500)];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(LocalityAware.select(&r), None);
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(100, hints, &views, &t);
        // symmetric topology: ties break deterministically to a valid peer
        let got = LocalityAware.select(&r).unwrap();
        assert!(got == 1 || got == 2);
    }

    #[test]
    fn fairness_caps_and_spreads() {
        let t = topo(3);
        let mut v1 = view(1, 500, 500);
        v1.our_bytes = 400;
        let mut v2 = view(2, 500, 500);
        v2.our_bytes = 100;
        let views = vec![view(0, 0, 0), v1, v2];
        let mut pol = RateLimitFairness { per_client_cap: 450 };
        let r = req(100, AllocHints::default(), &views, &t);
        // peer1 would exceed the cap (400+100 > 450): must pick peer2.
        assert_eq!(pol.select(&r), Some(2));
        let mut pol = RateLimitFairness { per_client_cap: 80 };
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(pol.select(&r), None, "cap below request size rejects");
    }

    #[test]
    fn interference_prefers_cool_peer() {
        let t = topo(3);
        let mut hot = view(1, 500, 500);
        hot.bw_demand = 500e9;
        let mut cool = view(2, 500, 500);
        cool.bw_demand = 1e9;
        let views = vec![view(0, 0, 0), hot, cool];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(InterferenceAware::default().select(&r), Some(2));
    }

    #[test]
    fn interference_falls_back_when_all_hot() {
        let t = topo(3);
        let mut a = view(1, 500, 500);
        a.bw_demand = 500e9;
        let mut b = view(2, 500, 500);
        b.bw_demand = 300e9;
        let views = vec![a, b];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(InterferenceAware::default().select(&r), Some(2), "least-hot fallback");
    }

    #[test]
    fn vectored_request_uses_total_and_contiguous() {
        let t = topo(3);
        // peer1: big budget, 300-byte segments; peer2: small budget, one
        // 400-byte segment.
        let views = vec![view(0, 0, 0), view(1, 1000, 300), view(2, 400, 400)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        // batch: total 600, largest element 250 -> peer2 lacks budget
        let r = PlacementRequest { size: 600, contiguous: 250, hints, views: &views, topo: &t };
        assert_eq!(BestFit.select(&r), Some(1));
        // a 350-byte element: nobody has both the budget and the segment
        let r = PlacementRequest { size: 600, contiguous: 350, hints, views: &views, topo: &t };
        assert_eq!(BestFit.select(&r), None);
    }

    #[test]
    fn stability_prefers_placid_peer() {
        let t = topo(3);
        let mut churny = view(1, 500, 500);
        churny.churn_per_sec = 0.4;
        let placid = view(2, 500, 500);
        let views = vec![view(0, 0, 0), churny, placid];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(StabilityAware.select(&r), Some(2));
    }
}
