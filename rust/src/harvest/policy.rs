//! Placement policies (§3.2 "Allocation policy"), tier edition.
//!
//! A [`PlacementPolicy`] answers two questions for every allocation:
//!
//! 1. **Which peer?** — [`PlacementPolicy::select`], the paper's
//!    per-policy choice (best-fit by default; locality / fairness /
//!    interference / stability variants per §3.2's sketch).
//! 2. **Which tier?** — [`PlacementPolicy::place_tiered`], the unified
//!    cross-tier decision: the policy-selected peer competes against
//!    host DRAM ([`crate::memsim::LinkModel::pcie5_host`]) and CXL
//!    ([`crate::memsim::LinkModel::cxl_mem`]) under **one cost model**
//!    (capacity, link queue depth, interference load), constrained by
//!    the caller's [`TierPreference`]. Peer-vs-host-vs-CXL stops being N
//!    ad-hoc consumer paths and becomes a single policy decision.

use super::api::{AllocHints, MemoryTier, TierPreference};
use super::monitor::PeerView;
use crate::memsim::{Ns, Topology};

/// Context a policy sees for one allocation request. A vectored
/// `alloc_many` batch is presented as a single request: `size` is the
/// aggregate and `contiguous` the largest single element, so the policy
/// is consulted once per batch rather than once per element.
pub struct PlacementRequest<'a> {
    /// Total bytes requested (whole batch for vectored allocations).
    pub size: u64,
    /// Largest single element — the segment size the arena must be able
    /// to carve contiguously (== `size` for scalar allocations).
    pub contiguous: u64,
    pub hints: AllocHints,
    pub views: &'a [PeerView],
    pub topo: &'a Topology,
}

impl PlacementRequest<'_> {
    /// Peers that can serve the request at all (not the compute GPU,
    /// enough budget for the total, a fitting segment for the largest
    /// element).
    pub fn feasible(&self) -> impl Iterator<Item = &PeerView> + '_ {
        self.views.iter().filter(move |v| {
            Some(v.device) != self.hints.compute_gpu
                && v.harvestable >= self.size
                && v.largest_free >= self.contiguous
        })
    }
}

/// Snapshot of one candidate tier as seen by the cross-tier cost model.
/// Built by the controller from the arenas, the link topology and the
/// monitor; one entry per feasible-ish tier (every harvestable peer,
/// host DRAM, CXL when attached).
#[derive(Debug, Clone, Copy)]
pub struct TierView {
    pub tier: MemoryTier,
    /// Bytes allocatable on the tier right now.
    pub free_bytes: u64,
    /// Largest contiguous segment on the tier.
    pub largest_free: u64,
    /// Unloaded latency of fetching the requested bytes from this tier
    /// to the compute GPU (the serve path the allocation exists for).
    pub fetch_ns: Ns,
    /// How long the fetch link stays busy with already-queued transfers
    /// (`busy_until − now`): the contention term of the cost model.
    pub queue_ns: Ns,
    /// Recent traffic on links touching the tier as a fraction of the
    /// fetch link's peak bandwidth: the interference term.
    pub load: f64,
    /// Tenant churn on the tier (peers only; 0 for host/CXL).
    pub churn_per_sec: f64,
}

impl TierView {
    /// The unified cost: unloaded fetch latency scaled by interference
    /// load, plus the current link queue. Lower is better; ties break to
    /// the faster tier class.
    pub fn cost_ns(&self) -> Ns {
        let scaled = (self.fetch_ns as f64 * (1.0 + self.load)) as Ns;
        scaled.saturating_add(self.queue_ns)
    }
}

/// One cross-tier placement request: the peer views (for the per-policy
/// peer choice) plus a [`TierView`] per candidate tier, under a caller
/// [`TierPreference`].
pub struct TieredPlacementRequest<'a> {
    pub size: u64,
    pub contiguous: u64,
    pub pref: TierPreference,
    pub hints: AllocHints,
    /// Peer views for [`PlacementPolicy::select`] (already filtered to
    /// harvestable devices).
    pub peer_views: &'a [PeerView],
    /// One view per candidate tier (peers, host, CXL when present).
    pub tier_views: &'a [TierView],
    pub topo: &'a Topology,
}

/// Chooses where an allocation lives.
pub trait PlacementPolicy {
    fn name(&self) -> &'static str;

    /// The per-policy *peer* choice among `req.views`, or `None` to
    /// reject the peer tier.
    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize>;

    /// The unified cross-tier decision. The default implementation asks
    /// [`PlacementPolicy::select`] which peer should represent the peer
    /// tier (preserving each policy's peer-selection character), then
    /// scores that peer against host DRAM and CXL with
    /// [`TierView::cost_ns`], honouring `req.pref`. Ties break to the
    /// faster tier class.
    fn place_tiered(&mut self, req: &TieredPlacementRequest<'_>) -> Option<MemoryTier> {
        let peer = {
            let pr = PlacementRequest {
                size: req.size,
                contiguous: req.contiguous,
                hints: req.hints,
                views: req.peer_views,
                topo: req.topo,
            };
            self.select(&pr)
        };
        let mut best: Option<(MemoryTier, Ns)> = None;
        for tv in req.tier_views {
            if !req.pref.allows(tv.tier) {
                continue;
            }
            if let MemoryTier::PeerHbm(g) = tv.tier {
                if peer != Some(g) {
                    continue; // only the policy's chosen peer competes
                }
            }
            if tv.free_bytes < req.size || tv.largest_free < req.contiguous {
                continue;
            }
            let cost = tv.cost_ns();
            let better = match best {
                None => true,
                Some((bt, bc)) => {
                    cost < bc || (cost == bc && tv.tier.speed_rank() < bt.speed_rank())
                }
            };
            if better {
                best = Some((tv.tier, cost));
            }
        }
        best.map(|(t, _)| t)
    }
}

/// Buildable placement-policy spec — the cluster-facing analogue of
/// [`crate::cluster::SchedulerSpec`]: every node needs its own policy
/// instance, so deployments carry this `Copy` spec and call
/// [`PlacementSpec::build`] per node. Also what the `policy_matrix`
/// bench sweeps.
///
/// ```
/// use harvest::harvest::PlacementSpec;
/// let spec = PlacementSpec::parse("stability").unwrap();
/// assert_eq!(spec, PlacementSpec::StabilityAware);
/// assert_eq!(spec.name(), "stability");
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlacementSpec {
    /// [`BestFit`] (the default).
    BestFit,
    /// [`FirstAvailable`].
    FirstAvailable,
    /// [`LocalityAware`].
    LocalityAware,
    /// [`StabilityAware`].
    StabilityAware,
    /// [`InterferenceAware`] with its hot-peer ceiling (bytes/sec).
    InterferenceAware { bw_demand_ceiling: f64 },
}

impl Default for PlacementSpec {
    fn default() -> Self {
        PlacementSpec::BestFit
    }
}

impl PlacementSpec {
    pub fn build(&self) -> Box<dyn PlacementPolicy> {
        match *self {
            PlacementSpec::BestFit => Box::new(BestFit),
            PlacementSpec::FirstAvailable => Box::new(FirstAvailable),
            PlacementSpec::LocalityAware => Box::new(LocalityAware),
            PlacementSpec::StabilityAware => Box::new(StabilityAware),
            PlacementSpec::InterferenceAware { bw_demand_ceiling } => {
                Box::new(InterferenceAware { bw_demand_ceiling })
            }
        }
    }

    /// Parse the config-file spelling (`harvest.placement`).
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name {
            "best-fit" => Ok(PlacementSpec::BestFit),
            "first-available" | "first" => Ok(PlacementSpec::FirstAvailable),
            "locality" => Ok(PlacementSpec::LocalityAware),
            "stability" => Ok(PlacementSpec::StabilityAware),
            "interference" => Ok(PlacementSpec::InterferenceAware {
                bw_demand_ceiling: InterferenceAware::default().bw_demand_ceiling,
            }),
            other => anyhow::bail!(
                "unknown placement policy `{other}` \
                 (best-fit | first-available | locality | stability | interference)"
            ),
        }
    }

    /// The built policy's [`PlacementPolicy::name`].
    pub fn name(&self) -> &'static str {
        match self {
            PlacementSpec::BestFit => "best-fit",
            PlacementSpec::FirstAvailable => "first-available",
            PlacementSpec::LocalityAware => "locality",
            PlacementSpec::StabilityAware => "stability",
            PlacementSpec::InterferenceAware { .. } => "interference",
        }
    }
}

/// The paper's default: the feasible peer whose fitting segment leaves
/// the least leftover (minimises fragmentation). Ties break to the lower
/// device index for determinism.
#[derive(Debug, Default, Clone)]
pub struct BestFit;

impl PlacementPolicy for BestFit {
    fn name(&self) -> &'static str {
        "best-fit"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible()
            .min_by_key(|v| (v.largest_free - req.contiguous, v.device))
            .map(|v| v.device)
    }
}

/// Simplest baseline: first feasible peer by index.
#[derive(Debug, Default, Clone)]
pub struct FirstAvailable;

impl PlacementPolicy for FirstAvailable {
    fn name(&self) -> &'static str {
        "first-available"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible().map(|v| v.device).next()
    }
}

/// Locality: prefer the peer with the lowest estimated fetch latency to
/// the compute GPU (NVLink-adjacent peers first on multi-hop fabrics).
#[derive(Debug, Default, Clone)]
pub struct LocalityAware;

impl PlacementPolicy for LocalityAware {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        let compute = req.hints.compute_gpu?;
        req.feasible()
            .filter_map(|v| {
                let lat = req.topo.estimate(
                    crate::memsim::DeviceId::Gpu(v.device),
                    crate::memsim::DeviceId::Gpu(compute),
                    req.size,
                )?;
                Some((lat, v.device))
            })
            .min()
            .map(|(_, d)| d)
    }
}

/// Fairness: rate-limit individual clients to `per_client_cap` bytes per
/// peer; among feasible peers pick the one where this client holds the
/// least.
#[derive(Debug, Clone)]
pub struct RateLimitFairness {
    pub per_client_cap: u64,
}

impl PlacementPolicy for RateLimitFairness {
    fn name(&self) -> &'static str {
        "fairness"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible()
            .filter(|v| v.our_bytes + req.size <= self.per_client_cap)
            .min_by_key(|v| (v.our_bytes, v.device))
            .map(|v| v.device)
    }
}

/// Interference: avoid peers whose links already move a lot of data.
#[derive(Debug, Clone)]
pub struct InterferenceAware {
    /// Peers above this bytes/sec demand are considered hot.
    pub bw_demand_ceiling: f64,
}

impl Default for InterferenceAware {
    fn default() -> Self {
        Self { bw_demand_ceiling: 100e9 } // 100 GB/s
    }
}

impl PlacementPolicy for InterferenceAware {
    fn name(&self) -> &'static str {
        "interference"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        let cool =
            req.feasible().filter(|v| v.bw_demand < self.bw_demand_ceiling).min_by(|a, b| {
                a.bw_demand.partial_cmp(&b.bw_demand).unwrap().then(a.device.cmp(&b.device))
            });
        cool.map(|v| v.device)
            // All peers hot: fall back to the least-hot feasible one.
            .or_else(|| {
                req.feasible()
                    .min_by(|a, b| a.bw_demand.partial_cmp(&b.bw_demand).unwrap())
                    .map(|v| v.device)
            })
    }
}

/// Stability: prefer peers with low tenant churn (fewer future
/// revocations).
#[derive(Debug, Default, Clone)]
pub struct StabilityAware;

impl PlacementPolicy for StabilityAware {
    fn name(&self) -> &'static str {
        "stability"
    }

    fn select(&mut self, req: &PlacementRequest<'_>) -> Option<usize> {
        req.feasible()
            .min_by(|a, b| {
                a.churn_per_sec
                    .partial_cmp(&b.churn_per_sec)
                    .unwrap()
                    .then(a.device.cmp(&b.device))
            })
            .map(|v| v.device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{Clock, Topology};

    fn view(device: usize, harvestable: u64, largest: u64) -> PeerView {
        PeerView {
            device,
            harvestable,
            largest_free: largest,
            churn_per_sec: 0.0,
            bw_demand: 0.0,
            our_bytes: 0,
        }
    }

    fn topo(n: usize) -> Topology {
        Topology::h100_node(Clock::new(), n)
    }

    fn req<'a>(size: u64, hints: AllocHints, views: &'a [PeerView], topo: &'a Topology)
        -> PlacementRequest<'a> {
        PlacementRequest { size, contiguous: size, hints, views, topo }
    }

    #[test]
    fn best_fit_minimises_leftover() {
        let t = topo(4);
        let views =
            vec![view(0, 1000, 1000), view(1, 500, 500), view(2, 300, 300), view(3, 100, 100)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(250, hints, &views, &t);
        assert_eq!(BestFit.select(&r), Some(2), "300-byte segment leaves least");
    }

    #[test]
    fn compute_gpu_never_selected() {
        let t = topo(2);
        let views = vec![view(0, 1000, 1000), view(1, 10, 10)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(100, hints, &views, &t);
        assert_eq!(BestFit.select(&r), None, "only feasible peer is the compute GPU itself");
    }

    #[test]
    fn infeasible_when_fragmented() {
        let t = topo(2);
        // plenty harvestable but no contiguous segment
        let views = vec![view(0, 0, 0), view(1, 1000, 50)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(100, hints, &views, &t);
        assert_eq!(BestFit.select(&r), None);
    }

    #[test]
    fn first_available_picks_lowest_index() {
        let t = topo(3);
        let views = vec![view(0, 0, 0), view(1, 500, 500), view(2, 500, 500)];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(FirstAvailable.select(&r), Some(1));
    }

    #[test]
    fn locality_needs_compute_hint() {
        let t = topo(3);
        let views = vec![view(1, 500, 500), view(2, 500, 500)];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(LocalityAware.select(&r), None);
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let r = req(100, hints, &views, &t);
        // symmetric topology: ties break deterministically to a valid peer
        let got = LocalityAware.select(&r).unwrap();
        assert!(got == 1 || got == 2);
    }

    #[test]
    fn fairness_caps_and_spreads() {
        let t = topo(3);
        let mut v1 = view(1, 500, 500);
        v1.our_bytes = 400;
        let mut v2 = view(2, 500, 500);
        v2.our_bytes = 100;
        let views = vec![view(0, 0, 0), v1, v2];
        let mut pol = RateLimitFairness { per_client_cap: 450 };
        let r = req(100, AllocHints::default(), &views, &t);
        // peer1 would exceed the cap (400+100 > 450): must pick peer2.
        assert_eq!(pol.select(&r), Some(2));
        let mut pol = RateLimitFairness { per_client_cap: 80 };
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(pol.select(&r), None, "cap below request size rejects");
    }

    #[test]
    fn interference_prefers_cool_peer() {
        let t = topo(3);
        let mut hot = view(1, 500, 500);
        hot.bw_demand = 500e9;
        let mut cool = view(2, 500, 500);
        cool.bw_demand = 1e9;
        let views = vec![view(0, 0, 0), hot, cool];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(InterferenceAware::default().select(&r), Some(2));
    }

    #[test]
    fn interference_falls_back_when_all_hot() {
        let t = topo(3);
        let mut a = view(1, 500, 500);
        a.bw_demand = 500e9;
        let mut b = view(2, 500, 500);
        b.bw_demand = 300e9;
        let views = vec![a, b];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(InterferenceAware::default().select(&r), Some(2), "least-hot fallback");
    }

    #[test]
    fn vectored_request_uses_total_and_contiguous() {
        let t = topo(3);
        // peer1: big budget, 300-byte segments; peer2: small budget, one
        // 400-byte segment.
        let views = vec![view(0, 0, 0), view(1, 1000, 300), view(2, 400, 400)];
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        // batch: total 600, largest element 250 -> peer2 lacks budget
        let r = PlacementRequest { size: 600, contiguous: 250, hints, views: &views, topo: &t };
        assert_eq!(BestFit.select(&r), Some(1));
        // a 350-byte element: nobody has both the budget and the segment
        let r = PlacementRequest { size: 600, contiguous: 350, hints, views: &views, topo: &t };
        assert_eq!(BestFit.select(&r), None);
    }

    #[test]
    fn stability_prefers_placid_peer() {
        let t = topo(3);
        let mut churny = view(1, 500, 500);
        churny.churn_per_sec = 0.4;
        let placid = view(2, 500, 500);
        let views = vec![view(0, 0, 0), churny, placid];
        let r = req(100, AllocHints::default(), &views, &t);
        assert_eq!(StabilityAware.select(&r), Some(2));
    }

    // -- cross-tier placement ---------------------------------------------

    fn tier_view(tier: MemoryTier, free: u64, fetch_ns: Ns) -> TierView {
        TierView {
            tier,
            free_bytes: free,
            largest_free: free,
            fetch_ns,
            queue_ns: 0,
            load: 0.0,
            churn_per_sec: 0.0,
        }
    }

    fn tiered<'a>(
        size: u64,
        pref: TierPreference,
        peer_views: &'a [PeerView],
        tier_views: &'a [TierView],
        topo: &'a Topology,
    ) -> TieredPlacementRequest<'a> {
        TieredPlacementRequest {
            size,
            contiguous: size,
            pref,
            hints: AllocHints { compute_gpu: Some(0), ..Default::default() },
            peer_views,
            tier_views,
            topo,
        }
    }

    #[test]
    fn idle_peer_beats_host_and_cxl() {
        let t = topo(2);
        let peers = vec![view(1, 1000, 1000)];
        let tiers = vec![
            tier_view(MemoryTier::PeerHbm(1), 1000, 10),
            tier_view(MemoryTier::CxlMem, 1000, 40),
            tier_view(MemoryTier::Host, 1000, 100),
        ];
        let r = tiered(100, TierPreference::FastestAvailable, &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), Some(MemoryTier::PeerHbm(1)));
    }

    #[test]
    fn queued_peer_link_spills_to_cxl_then_host() {
        let t = topo(2);
        let peers = vec![view(1, 1000, 1000)];
        let mut busy_peer = tier_view(MemoryTier::PeerHbm(1), 1000, 10);
        busy_peer.queue_ns = 1_000; // demand queue on the fetch link
        let tiers = vec![
            busy_peer,
            tier_view(MemoryTier::CxlMem, 1000, 40),
            tier_view(MemoryTier::Host, 1000, 100),
        ];
        let r = tiered(100, TierPreference::FastestAvailable, &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), Some(MemoryTier::CxlMem));
        // CXL full -> host wins
        let tiers = vec![busy_peer, tier_view(MemoryTier::CxlMem, 0, 40),
                         tier_view(MemoryTier::Host, 1000, 100)];
        let r = tiered(100, TierPreference::FastestAvailable, &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), Some(MemoryTier::Host));
    }

    #[test]
    fn interference_load_scales_cost() {
        let t = topo(2);
        let peers = vec![view(1, 1000, 1000)];
        let mut loaded_peer = tier_view(MemoryTier::PeerHbm(1), 1000, 60);
        loaded_peer.load = 1.0; // saturated link: cost doubles to 120
        let tiers = vec![loaded_peer, tier_view(MemoryTier::Host, 1000, 100)];
        let r = tiered(100, TierPreference::FastestAvailable, &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), Some(MemoryTier::Host));
    }

    #[test]
    fn preference_constrains_tiers() {
        let t = topo(2);
        let peers = vec![view(1, 0, 0)]; // peer full
        let tiers = vec![
            tier_view(MemoryTier::PeerHbm(1), 0, 10),
            tier_view(MemoryTier::CxlMem, 1000, 40),
            tier_view(MemoryTier::Host, 1000, 100),
        ];
        // peers-only preference: nothing admissible
        let r = tiered(100, TierPreference::PEER_ONLY, &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), None);
        // at-least-CXL admits CXL but not host
        let r = tiered(100, TierPreference::AtLeast(MemoryTier::CxlMem), &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), Some(MemoryTier::CxlMem));
    }

    #[test]
    fn policy_peer_choice_feeds_tier_decision() {
        // Best-fit picks peer 2 (tighter segment); the tier decision must
        // score *that* peer, not peer 1.
        let t = topo(3);
        let peers = vec![view(1, 1000, 1000), view(2, 300, 300)];
        let tiers = vec![
            tier_view(MemoryTier::PeerHbm(1), 1000, 10),
            tier_view(MemoryTier::PeerHbm(2), 300, 10),
            tier_view(MemoryTier::Host, 10_000, 100),
        ];
        let r = tiered(250, TierPreference::FastestAvailable, &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), Some(MemoryTier::PeerHbm(2)));
    }

    #[test]
    fn equal_cost_ties_break_to_faster_class() {
        let t = topo(2);
        let peers = vec![view(1, 1000, 1000)];
        let tiers = vec![
            tier_view(MemoryTier::Host, 1000, 50),
            tier_view(MemoryTier::CxlMem, 1000, 50),
        ];
        let r = tiered(100, TierPreference::FastestAvailable, &peers, &tiers, &t);
        assert_eq!(BestFit.place_tiered(&r), Some(MemoryTier::CxlMem));
    }
}
