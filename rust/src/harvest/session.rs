//! Lease-based client surface: sessions, RAII tier-carrying leases, and
//! the unified transfer builder.
//!
//! Consumers open one [`HarvestSession`] per subsystem (the KV offload
//! manager, the MoE rebalancer, …) and get:
//!
//! * [`Lease`] — an RAII handle replacing the bare `HandleId`. The
//!   payload kind, durability, client identity and **resident tier**
//!   ride on the lease ([`Lease::tier`] stays current across
//!   migrations); releasing consumes it (double-free is
//!   unrepresentable), and a lease dropped without release is reclaimed
//!   by the runtime's leak sweep, so per-tier `bytes_on` accounting can
//!   never drift.
//! * [`HarvestSession::alloc`] / [`HarvestSession::alloc_many`] — every
//!   allocation names a [`TierPreference`]; the placement policy scores
//!   peer HBM, host DRAM and CXL under one cost model and the returned
//!   leases carry the chosen tier. `alloc_many` is vectored and
//!   all-or-nothing: one policy consultation for the whole batch, one
//!   tier, full rollback on partial placement failure.
//! * [`HarvestSession::drain_revocations`] — the pull-model replacement
//!   for `harvest_register_cb`: the controller finishes the whole
//!   revocation pipeline (drain DMA → invalidate → free, or the
//!   demotion migration) before the event becomes drainable.
//! * [`Transfer`] — one builder for every data movement (`copy_in` and
//!   `fetch_to` unified), with per-lease DMA tagging, optional
//!   scattered-descriptor chunking for paged KV, a
//!   [`Transfer::background`] mode that attributes a batch as prefetch
//!   bandwidth in the peer monitor, and [`Transfer::migrate`] to move a
//!   live lease between tiers (demotion under pressure, promotion when
//!   capacity opens) as a first-class, monitored, revocation-safe op.
//!
//! # Example: open → alloc_many → Transfer → migrate → release
//!
//! ```
//! use harvest::harvest::{AllocHints, HarvestConfig, HarvestRuntime, MemoryTier,
//!                        PayloadKind, TierPreference, Transfer};
//! use harvest::memsim::{DeviceId, NodeSpec, SimNode};
//!
//! let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()),
//!                                  HarvestConfig::for_node(2));
//! let session = hr.open_session(PayloadKind::KvBlock);
//! let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
//!
//! // Vectored, all-or-nothing: one policy consultation, one tier for
//! // the whole batch, full rollback on failure. On an idle fabric the
//! // cost model picks peer HBM.
//! let leases =
//!     session.alloc_many(&mut hr, &[1 << 20, 1 << 20], TierPreference::FastestAvailable,
//!                        hints)?;
//! assert_eq!(leases.len(), 2);
//! assert_eq!(leases[0].tier(), MemoryTier::PeerHbm(1));
//! assert_eq!(leases[0].tier(), leases[1].tier());
//!
//! // One batched submission: populate both entries, then serve a hit.
//! let report = Transfer::new()
//!     .populate(&leases[0], DeviceId::Host)
//!     .populate(&leases[1], DeviceId::Host)
//!     .fetch(&leases[0], 0)
//!     .submit(&mut hr)?;
//! assert_eq!(report.events.len(), 3);
//! assert_eq!(report.bytes, 3 << 20);
//!
//! // Demote one lease to host DRAM: the lease survives, carrying its
//! // new tier; later fetches ride the PCIe link instead.
//! Transfer::new().migrate(&leases[1], MemoryTier::Host).submit(&mut hr)?;
//! assert_eq!(leases[1].tier(), MemoryTier::Host);
//!
//! // Release consumes each lease — releasing twice does not typecheck.
//! for lease in leases {
//!     session.release(&mut hr, lease)?;
//! }
//! assert_eq!(hr.live_bytes_on(1), 0);
//! assert_eq!(hr.live_bytes_on_tier(MemoryTier::Host), 0);
//! # Ok::<(), harvest::harvest::HarvestError>(())
//! ```

use super::api::{AllocHints, HarvestError, HarvestHandle, LeaseId, MemoryTier, TierPreference};
use super::controller::HarvestRuntime;
use super::events::{PayloadKind, RevocationEvent};
use crate::memsim::{AllocId, CopyEvent, DeviceId, Ns};
use crate::obs::trace::{self, Subsystem};
use std::cell::{Cell, RefCell};
use std::rc::Rc;

/// Identifier of a session within one runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(pub u32);

/// Shared drop-inbox: leases dropped without an explicit release record
/// their id here; the runtime sweeps it at allocation / pressure / time
/// boundaries and frees whatever is still live.
pub(crate) type ReclaimInbox = Rc<RefCell<Vec<LeaseId>>>;

// ---------------------------------------------------------------------
// Lease
// ---------------------------------------------------------------------

/// RAII ownership of one harvest allocation, resident on exactly one
/// [`MemoryTier`] at a time.
///
/// A `Lease` is not `Clone`/`Copy`: exactly one owner exists, and the
/// only ways it ends are
///
/// 1. [`HarvestSession::release`] — explicit, ordered free (consumes the
///    lease, so releasing twice does not typecheck);
/// 2. drop-revocation by the runtime — the lease object the consumer
///    still holds goes stale, and the session's event queue says so
///    (a *demotion* is not an ending: the lease lives on, on the slower
///    tier — [`Lease::tier`] tracks it);
/// 3. dropping it — the id lands in the reclaim inbox and the runtime
///    frees the bytes at its next sweep. Leaks are therefore bounded to
///    one sweep interval, never permanent.
#[derive(Debug)]
pub struct Lease {
    handle: HarvestHandle,
    /// Residency cell shared with the runtime: migrations and demotions
    /// update it in place, so the lease always knows its current tier.
    tier: Rc<Cell<MemoryTier>>,
    kind: PayloadKind,
    session: SessionId,
    reclaim: ReclaimInbox,
    /// True until released/revoked bookkeeping disarms the drop hook.
    armed: bool,
}

impl Lease {
    pub(crate) fn new(
        handle: HarvestHandle,
        tier: Rc<Cell<MemoryTier>>,
        kind: PayloadKind,
        session: SessionId,
        reclaim: ReclaimInbox,
    ) -> Self {
        Self { handle, tier, kind, session, reclaim, armed: true }
    }

    pub fn id(&self) -> LeaseId {
        self.handle.id
    }

    /// The tier currently holding the bytes. Stays correct across
    /// [`Transfer::migrate`] and controller demotions (the cell is
    /// shared with the runtime); after a drop-revocation it reports the
    /// last tier the lease lived on.
    pub fn tier(&self) -> MemoryTier {
        self.tier.get()
    }

    /// The peer GPU index, when the lease is resident in peer HBM.
    pub fn peer(&self) -> Option<usize> {
        self.tier().peer_gpu()
    }

    pub fn size(&self) -> u64 {
        self.handle.size
    }

    pub fn durability(&self) -> super::api::Durability {
        self.handle.durability
    }

    pub fn client(&self) -> Option<u32> {
        self.handle.client
    }

    pub fn kind(&self) -> PayloadKind {
        self.kind
    }

    pub fn session(&self) -> SessionId {
        self.session
    }

    /// The raw placement record as of allocation time (for metrics /
    /// interop with the deprecated surface). `raw().tier` is a snapshot;
    /// [`Lease::tier`] is current.
    pub fn raw(&self) -> HarvestHandle {
        self.handle
    }

    /// Disarm the drop hook and surrender the raw handle. Used by the
    /// release path and by the deprecated shim (which manages lifetime
    /// manually, as the paper's C-style API did).
    pub fn into_raw(mut self) -> HarvestHandle {
        self.armed = false;
        self.handle
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.armed {
            self.reclaim.borrow_mut().push(self.handle.id);
        }
    }
}

// ---------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------

/// A consumer's identity against one [`HarvestRuntime`]: a payload kind,
/// an optional client id for fairness accounting, and a private
/// revocation queue inside the runtime. Copyable — it is just an
/// address; the runtime owns the queue state.
#[derive(Debug, Clone, Copy)]
pub struct HarvestSession {
    id: SessionId,
    kind: PayloadKind,
    client: Option<u32>,
    /// Identity of the runtime this session was opened against. Session
    /// and lease ids are runtime-local, so addressing a *different*
    /// runtime would at best panic on an out-of-range index and at worst
    /// silently drain another consumer's events — checked loudly instead.
    runtime: usize,
}

impl HarvestSession {
    /// Open a session of `kind` against `hr`.
    pub fn open(hr: &mut HarvestRuntime, kind: PayloadKind) -> Self {
        let id = hr.register_session(kind);
        Self { id, kind, client: None, runtime: hr.runtime_tag() }
    }

    /// Open with a client identity; it is stamped onto every allocation
    /// this session makes (unless the hints override it).
    pub fn open_for_client(hr: &mut HarvestRuntime, kind: PayloadKind, client: u32) -> Self {
        let id = hr.register_session(kind);
        Self { id, kind, client: Some(client), runtime: hr.runtime_tag() }
    }

    pub fn id(&self) -> SessionId {
        self.id
    }

    pub fn kind(&self) -> PayloadKind {
        self.kind
    }

    fn check_bound(&self, hr: &HarvestRuntime) {
        assert_eq!(
            self.runtime,
            hr.runtime_tag(),
            "HarvestSession used against a different HarvestRuntime than it was opened on"
        );
    }

    fn effective_hints(&self, hints: AllocHints) -> AllocHints {
        AllocHints { client: hints.client.or(self.client), ..hints }
    }

    /// §3.2 `harvest_alloc`, tiered-lease edition: select a tier under
    /// the placement policy (constrained by `pref`) and return an RAII
    /// lease carrying its resident tier.
    pub fn alloc(
        &self,
        hr: &mut HarvestRuntime,
        size: u64,
        pref: TierPreference,
        hints: AllocHints,
    ) -> Result<Lease, HarvestError> {
        self.check_bound(hr);
        let handle = hr.alloc_raw(self.id, size, pref, self.effective_hints(hints))?;
        Ok(Lease::new(handle, hr.tier_cell(handle.id), self.kind, self.id, hr.reclaim_inbox()))
    }

    /// Vectored allocation with all-or-nothing semantics: the placement
    /// policy is consulted once for the aggregate request, every element
    /// lands on the same tier, and a partial placement failure rolls the
    /// whole batch back (no bytes remain allocated, no leases escape).
    pub fn alloc_many(
        &self,
        hr: &mut HarvestRuntime,
        sizes: &[u64],
        pref: TierPreference,
        hints: AllocHints,
    ) -> Result<Vec<Lease>, HarvestError> {
        self.check_bound(hr);
        let handles = hr.alloc_many_raw(self.id, sizes, pref, self.effective_hints(hints))?;
        let inbox = hr.reclaim_inbox();
        Ok(handles
            .into_iter()
            .map(|h| {
                Lease::new(h, hr.tier_cell(h.id), self.kind, self.id, Rc::clone(&inbox))
            })
            .collect())
    }

    /// §3.2 `harvest_free`, lease edition: ordered, explicit
    /// deallocation (drains DMA tagged with the lease first). Consumes
    /// the lease — double release does not typecheck. No revocation
    /// event is produced: the application initiated the free.
    pub fn release(&self, hr: &mut HarvestRuntime, lease: Lease) -> Result<(), HarvestError> {
        self.check_bound(hr);
        let handle = lease.into_raw();
        hr.free(handle.id)
    }

    /// Drain this session's pending revocation events, oldest first.
    /// Consumers call this at tick boundaries (decode-pass start, KV
    /// manager entry points); every event refers to a lease whose
    /// pipeline the runtime has already completed — drained, invalidated
    /// and freed for drops; drained and migrated for demotions.
    pub fn drain_revocations(&self, hr: &mut HarvestRuntime) -> Vec<RevocationEvent> {
        self.check_bound(hr);
        hr.drain_session(self.id)
    }

    /// Pending (undrained) event count, without draining.
    pub fn pending_revocations(&self, hr: &HarvestRuntime) -> usize {
        self.check_bound(hr);
        hr.session_queue_len(self.id)
    }

    /// Start a transfer batch (sugar for [`Transfer::new`]).
    pub fn transfer(&self) -> Transfer {
        Transfer::new()
    }
}

// ---------------------------------------------------------------------
// Transfer builder
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum TransferOp {
    /// Populate the cache: `src` → the lease's resident tier.
    Populate { lease: LeaseId, src: DeviceId },
    /// Serve a hit: the lease's resident tier → the compute GPU.
    Fetch { lease: LeaseId, compute: usize },
    /// An untagged raw move (diagnostics, synthetic link load).
    Raw { src: DeviceId, dst: DeviceId, bytes: u64 },
    /// Move the lease's bytes to another tier (demotion / promotion).
    Migrate { lease: LeaseId, to: MemoryTier },
    /// Shrink the lease in place to `ratio` percent (modeled KV
    /// compression; no bytes move).
    Compress { lease: LeaseId, ratio: u32 },
    /// Re-grow a compressed lease to its original size on its tier.
    Decompress { lease: LeaseId },
}

/// Report of one submitted transfer batch.
#[derive(Debug, Clone, Default)]
pub struct TransferReport {
    /// One entry per op, in submission order.
    pub events: Vec<CopyEvent>,
    /// Total bytes moved.
    pub bytes: u64,
    /// Completion time of the batch (max op end; current virtual time if
    /// the batch was empty).
    pub end: Ns,
}

impl TransferReport {
    /// Completion of the last submitted op (panics on empty batches).
    pub fn last(&self) -> &CopyEvent {
        self.events.last().expect("non-empty transfer batch")
    }
}

/// Batched-DMA builder unifying the old `copy_in` / `fetch_to` pair and
/// the new cross-tier migration.
///
/// Ops accumulate, then [`Transfer::submit`] schedules them in order on
/// the simulated DMA engine. Lease-addressed ops are tagged with the
/// lease id, so the revocation pipeline's drain-by-tag covers them; raw
/// ops are untagged. `chunked(n)` batches every op into scattered
/// descriptors of at most `n` bytes (paged-KV reload granularity).
#[derive(Debug, Default)]
pub struct Transfer {
    ops: Vec<TransferOp>,
    chunk_bytes: Option<u64>,
    background: bool,
}

impl Transfer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Split every op into scattered DMA descriptors of at most
    /// `descriptor_bytes` (e.g. [`crate::kv::manager::RELOAD_CHUNK_BYTES`]).
    pub fn chunked(mut self, descriptor_bytes: u64) -> Self {
        assert!(descriptor_bytes > 0, "descriptor size must be positive");
        self.chunk_bytes = Some(descriptor_bytes);
        self
    }

    /// Mark this batch as *background* (prefetch) traffic: its tier
    /// traffic is recorded as prefetch bandwidth in the
    /// [`super::monitor::PeerMonitor`] — still visible to the
    /// interference policy, but attributed separately from demand
    /// traffic. Background ops keep their lease tags, so the §3.2
    /// drain-before-free barrier covers them exactly like demand DMA; to
    /// keep that barrier off the hot path, consumers defer the lease
    /// release until the background copy has completed (see
    /// [`crate::kv::manager::KvOffloadManager::submit_prefetch`]).
    pub fn background(mut self) -> Self {
        self.background = true;
        self
    }

    /// Queue a populate: copy `lease.size()` bytes from `src` into the
    /// lease's resident tier (the old `copy_in`).
    pub fn populate(mut self, lease: &Lease, src: DeviceId) -> Self {
        self.ops.push(TransferOp::Populate { lease: lease.id(), src });
        self
    }

    /// Queue a fetch: copy the lease's bytes from its resident tier to
    /// `compute_gpu` (the old `fetch_to` — the fast path the paper
    /// measures; over NVLink from peers, PCIe from host, the CXL link
    /// from the expander).
    pub fn fetch(mut self, lease: &Lease, compute_gpu: usize) -> Self {
        self.ops.push(TransferOp::Fetch { lease: lease.id(), compute: compute_gpu });
        self
    }

    /// Queue an untagged raw move between arbitrary devices (diagnostic
    /// traffic; consumers move cached state through lease-addressed ops).
    pub fn raw(mut self, src: DeviceId, dst: DeviceId, bytes: u64) -> Self {
        self.ops.push(TransferOp::Raw { src, dst, bytes });
        self
    }

    /// Queue a migration: allocate on tier `to`, copy the lease's bytes
    /// over (tagged — the §3.2 drain barrier covers the move), release
    /// the source segment, and update the lease's resident tier in
    /// place. Demotion (peer→host under pressure) and promotion
    /// (host→peer when capacity opens) are the two canonical uses; a
    /// same-tier migrate is a no-op. Tier pairs with a direct link
    /// (peer↔host, peer↔CXL) copy straight across; host↔CXL has no
    /// direct link and is staged through the least-loaded GPU-adjacent
    /// link pair (two tagged hops).
    pub fn migrate(mut self, lease: &Lease, to: MemoryTier) -> Self {
        self.ops.push(TransferOp::Migrate { lease: lease.id(), to });
        self
    }

    /// Queue an in-place compression: shrink the lease to `ratio_pct`
    /// percent of its current size (modeled layer-wise KV compression —
    /// see [`crate::coldtier::Compressor`]), releasing the tail to its
    /// arena immediately. Compression is a *placement action*: it moves
    /// no bytes and is free in virtual time; the modeled cost is paid
    /// decode-side when the consumer next reloads the payload and
    /// charges the compressor's decompression rate. Compressing an
    /// already-compressed lease is a no-op.
    ///
    /// # Panics
    /// If `ratio_pct` is outside `1..=99`.
    pub fn compress(mut self, lease: &Lease, ratio_pct: u32) -> Self {
        assert!((1..=99).contains(&ratio_pct), "compress ratio must be in 1..=99");
        self.ops.push(TransferOp::Compress { lease: lease.id(), ratio: ratio_pct });
        self
    }

    /// Queue a decompression: re-grow a compressed lease to its original
    /// byte count on its current tier (fails the submission with
    /// [`HarvestError::NoCapacity`] when the arena cannot hold the
    /// full-size segment again). Decompressing an uncompressed lease is
    /// a no-op.
    pub fn decompress(mut self, lease: &Lease) -> Self {
        self.ops.push(TransferOp::Decompress { lease: lease.id() });
        self
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Schedule every queued op, in order, all-or-nothing: before
    /// anything moves, every lease-addressed op is checked live
    /// ([`HarvestError::StaleLease`] otherwise) and every migration's
    /// destination segment is *reserved* — a reservation that fails
    /// (even through fragmentation) rolls its siblings back and returns
    /// [`HarvestError::NoCapacity`] with nothing scheduled. Execution
    /// then resolves each op's devices against the lease's residency *at
    /// that point in the batch*, so a fetch queued after a migrate reads
    /// from the destination tier, not a stale snapshot.
    pub fn submit(self, hr: &mut HarvestRuntime) -> Result<TransferReport, HarvestError> {
        // Pass 1: validate liveness; drop migrations that are already
        // no-ops against the current residency.
        let mut ops: Vec<TransferOp> = Vec::with_capacity(self.ops.len());
        for op in &self.ops {
            match *op {
                TransferOp::Populate { lease, .. } | TransferOp::Fetch { lease, .. } => {
                    hr.handle_info(lease).ok_or(HarvestError::StaleLease(lease))?;
                    ops.push(*op);
                }
                TransferOp::Raw { .. } => ops.push(*op),
                TransferOp::Migrate { lease, to } => {
                    let h = hr.handle_info(lease).ok_or(HarvestError::StaleLease(lease))?;
                    if h.tier != to {
                        ops.push(*op);
                    }
                }
                TransferOp::Compress { lease, .. } | TransferOp::Decompress { lease } => {
                    hr.handle_info(lease).ok_or(HarvestError::StaleLease(lease))?;
                    ops.push(*op);
                }
            }
        }
        // Pass 2: reserve every migration destination; roll back on the
        // first failure so a rejected batch leaves no allocation behind.
        let mut reserved: Vec<(MemoryTier, AllocId)> = Vec::new();
        for op in &ops {
            if let TransferOp::Migrate { lease, to } = *op {
                match hr.prepare_migration(lease, to) {
                    Ok(a) => reserved.push((to, a)),
                    Err(e) => {
                        for (t, a) in reserved {
                            hr.unprepare_migration(t, a);
                        }
                        return Err(e);
                    }
                }
            }
        }
        let mut reservations = reserved.into_iter();
        // Pass 3: execute in order, resolving residency fresh per op.
        let mut report =
            TransferReport { events: Vec::with_capacity(ops.len()), bytes: 0, end: 0 };
        for op in ops {
            let op_name = match op {
                TransferOp::Populate { .. } => "populate",
                TransferOp::Fetch { .. } => "fetch",
                TransferOp::Raw { .. } => "raw",
                TransferOp::Migrate { .. } => "migrate",
                TransferOp::Compress { .. } => "compress",
                TransferOp::Decompress { .. } => "decompress",
            };
            let (ev, bytes) = match op {
                TransferOp::Populate { lease, src } => {
                    let h = hr.handle_info(lease).expect("validated above");
                    let ev = self.copy(hr, src, h.tier.device(), h.size, Some(lease.0));
                    hr.record_tier_traffic(h.tier, ev.end, h.size, self.background);
                    (ev, h.size)
                }
                TransferOp::Fetch { lease, compute } => {
                    let h = hr.handle_info(lease).expect("validated above");
                    let ev =
                        self.copy(hr, h.tier.device(), DeviceId::Gpu(compute), h.size, Some(lease.0));
                    hr.record_tier_traffic(h.tier, ev.end, h.size, self.background);
                    (ev, h.size)
                }
                TransferOp::Raw { src, dst, bytes } => {
                    (self.copy(hr, src, dst, bytes, None), bytes)
                }
                TransferOp::Migrate { lease, to } => {
                    let (_, dst_alloc) =
                        reservations.next().expect("one reservation per migrate");
                    let ev =
                        hr.commit_migration(lease, to, dst_alloc, self.background, self.chunk_bytes);
                    (ev, ev.bytes)
                }
                // Compression actions move no bytes: they reshape the
                // lease's arena footprint at the current virtual time.
                TransferOp::Compress { lease, ratio } => {
                    let h = hr.handle_info(lease).expect("validated above");
                    hr.compress_lease(lease, ratio)?;
                    let now = hr.node.clock.now();
                    let dev = h.tier.device();
                    (CopyEvent { start: now, end: now, bytes: 0, src: dev, dst: dev }, 0)
                }
                TransferOp::Decompress { lease } => {
                    let h = hr.handle_info(lease).expect("validated above");
                    hr.decompress_lease(lease)?;
                    let now = hr.node.clock.now();
                    let dev = h.tier.device();
                    (CopyEvent { start: now, end: now, bytes: 0, src: dev, dst: dev }, 0)
                }
            };
            if trace::is_enabled() {
                trace::span(
                    Subsystem::Transfer,
                    op_name,
                    ev.start,
                    ev.end,
                    &[
                        ("src", trace::dev(ev.src)),
                        ("dst", trace::dev(ev.dst)),
                        ("bytes", ev.bytes),
                        ("bg", self.background as u64),
                    ],
                );
            }
            report.bytes += bytes;
            report.end = report.end.max(ev.end);
            report.events.push(ev);
        }
        if report.events.is_empty() {
            report.end = hr.node.clock.now();
        }
        Ok(report)
    }

    /// One (possibly chunked) copy on the simulated DMA engine. The SSD
    /// hangs behind host DRAM only, so GPU/CXL endpoints reach it as a
    /// staged multi-hop copy (chunking does not apply there — the NVMe
    /// hop dominates and carries its own half-saturation model).
    fn copy(
        &self,
        hr: &mut HarvestRuntime,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        tag: Option<u64>,
    ) -> CopyEvent {
        match (src, dst) {
            (DeviceId::Ssd, DeviceId::Gpu(_)) | (DeviceId::Gpu(_), DeviceId::Ssd) => {
                return hr.node.copy_path(&[src, DeviceId::Host, dst], bytes, tag);
            }
            (DeviceId::Ssd, DeviceId::Cxl) => {
                return hr
                    .node
                    .copy_path(&[src, DeviceId::Host, DeviceId::Gpu(0), dst], bytes, tag);
            }
            (DeviceId::Cxl, DeviceId::Ssd) => {
                return hr
                    .node
                    .copy_path(&[src, DeviceId::Gpu(0), DeviceId::Host, dst], bytes, tag);
            }
            _ => {}
        }
        match self.chunk_bytes {
            Some(chunk) if bytes > chunk => {
                hr.node.copy_scattered(src, dst, bytes, bytes.div_ceil(chunk), tag)
            }
            _ => hr.node.copy(src, dst, bytes, tag),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::api::Durability;
    use crate::harvest::controller::HarvestConfig;
    use crate::memsim::{NodeSpec, SimNode};

    const MIB: u64 = 1 << 20;
    const GIB: u64 = 1 << 30;

    fn rt() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    fn rt_cxl() -> HarvestRuntime {
        HarvestRuntime::new(
            SimNode::new(NodeSpec::h100x2().with_cxl(64 * GIB)),
            HarvestConfig::for_node(2),
        )
    }

    fn hints() -> AllocHints {
        AllocHints { compute_gpu: Some(0), ..Default::default() }
    }

    const PEERS: TierPreference = TierPreference::PEER_ONLY;

    #[test]
    fn lease_carries_typed_metadata_and_tier() {
        let mut hr = rt();
        let s = HarvestSession::open_for_client(&mut hr, PayloadKind::KvBlock, 7);
        let lease = s
            .alloc(
                &mut hr,
                2 * MIB,
                PEERS,
                AllocHints { durability: Durability::Lossy, ..hints() },
            )
            .unwrap();
        assert_eq!(lease.kind(), PayloadKind::KvBlock);
        assert_eq!(lease.durability(), Durability::Lossy);
        assert_eq!(lease.client(), Some(7), "session client stamped onto the lease");
        assert_eq!(lease.tier(), MemoryTier::PeerHbm(1));
        assert_eq!(lease.peer(), Some(1));
        assert_eq!(lease.size(), 2 * MIB);
        s.release(&mut hr, lease).unwrap();
        assert_eq!(hr.live_bytes_on(1), 0);
    }

    #[test]
    fn dropped_lease_is_reclaimed_by_sweep() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::Generic);
        let lease = s.alloc(&mut hr, 4 * MIB, PEERS, hints()).unwrap();
        let id = lease.id();
        drop(lease); // leaked, not released
        assert!(hr.is_live(id), "not yet swept");
        assert_eq!(hr.sweep_leaked(), 1);
        assert!(!hr.is_live(id));
        assert_eq!(hr.live_bytes_on(1), 0);
        assert_eq!(hr.node.gpus[1].hbm.used(), 0);
        // no revocation event: the app dropped it, nothing to repair
        assert!(s.drain_revocations(&mut hr).is_empty());
    }

    #[test]
    fn release_consumes_and_revoked_lease_is_stale() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::Generic);
        let lease = s.alloc(&mut hr, MIB, PEERS, hints()).unwrap();
        let id = lease.id();
        s.release(&mut hr, lease).unwrap();
        // `lease` is moved — releasing again does not compile. The raw id
        // is stale:
        assert_eq!(hr.free(id), Err(HarvestError::StaleLease(id)));
        // a revoked lease's transfers fail closed
        let lease2 = s.alloc(&mut hr, MIB, PEERS, hints()).unwrap();
        hr.revoke(lease2.id(), crate::harvest::api::RevocationReason::PolicyEviction);
        let err = Transfer::new().fetch(&lease2, 0).submit(&mut hr).unwrap_err();
        assert_eq!(err, HarvestError::StaleLease(lease2.id()));
    }

    #[test]
    fn alloc_many_is_all_or_nothing() {
        let mut hr = rt();
        // cap the only peer at 3 GiB
        hr.config.mig[1] = crate::harvest::MigConfig::CachePartition { bytes: 3 * GIB };
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        // 2 GiB fits...
        let got = s.alloc_many(&mut hr, &[GIB, GIB], PEERS, hints()).unwrap();
        assert_eq!(got.len(), 2);
        assert!(
            got.iter().all(|l| l.tier() == MemoryTier::PeerHbm(1)),
            "one tier for the whole batch"
        );
        assert_eq!(hr.live_bytes_on(1), 2 * GIB);
        for l in got {
            s.release(&mut hr, l).unwrap();
        }
        // ...4 GiB does not: nothing must stick
        let before_fail = hr.alloc_failures;
        let err = s.alloc_many(&mut hr, &[GIB, GIB, GIB, GIB], PEERS, hints()).unwrap_err();
        assert!(matches!(err, HarvestError::NoCapacity { requested } if requested == 4 * GIB));
        assert_eq!(hr.live_bytes_on(1), 0, "rollback left no bytes");
        assert_eq!(hr.node.gpus[1].hbm.used(), 0);
        assert!(hr.alloc_failures > before_fail);
    }

    #[test]
    fn alloc_many_spills_whole_batch_to_next_tier() {
        let mut hr = rt();
        // peer holds 3 GiB at most; fastest-available rolls the whole
        // batch to host DRAM rather than splitting it
        hr.config.mig[1] = crate::harvest::MigConfig::CachePartition { bytes: 3 * GIB };
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let got = s
            .alloc_many(
                &mut hr,
                &[GIB, GIB, GIB, GIB],
                TierPreference::FastestAvailable,
                hints(),
            )
            .unwrap();
        assert!(got.iter().all(|l| l.tier() == MemoryTier::Host), "one tier per batch");
        assert_eq!(hr.live_bytes_on(1), 0);
        assert_eq!(hr.live_bytes_on_tier(MemoryTier::Host), 4 * GIB);
        for l in got {
            s.release(&mut hr, l).unwrap();
        }
    }

    #[test]
    fn alloc_many_rejects_zero_and_accepts_empty() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::Generic);
        assert!(s.alloc_many(&mut hr, &[], PEERS, hints()).unwrap().is_empty());
        assert_eq!(
            s.alloc_many(&mut hr, &[MIB, 0], PEERS, hints()).unwrap_err(),
            HarvestError::ZeroSize
        );
        assert_eq!(hr.live_bytes_on(1), 0);
    }

    #[test]
    fn transfer_builder_orders_and_tags() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::ExpertWeights);
        let a = s.alloc(&mut hr, 32 * MIB, PEERS, hints()).unwrap();
        let b = s.alloc(&mut hr, 32 * MIB, PEERS, hints()).unwrap();
        let report = Transfer::new()
            .populate(&a, DeviceId::Host)
            .populate(&b, DeviceId::Host)
            .fetch(&a, 0)
            .submit(&mut hr)
            .unwrap();
        assert_eq!(report.events.len(), 3);
        assert_eq!(report.bytes, 96 * MIB);
        assert_eq!(report.events[2].src, DeviceId::Gpu(1));
        assert_eq!(report.events[2].dst, DeviceId::Gpu(0));
        assert!(report.end >= report.events[2].end);
        // per-lease tagging: draining lease a's tag waits for its ops
        let drained = hr.node.dma.drain_tag(&hr.node.topo, a.id().0);
        assert!(drained >= report.events[2].end);
        s.release(&mut hr, a).unwrap();
        s.release(&mut hr, b).unwrap();
    }

    #[test]
    fn fetch_resolves_resident_tier_device() {
        // Host- and CXL-tier leases fetch over their own links.
        let mut hr = rt_cxl();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let host =
            s.alloc(&mut hr, MIB, TierPreference::Pinned(MemoryTier::Host), hints()).unwrap();
        let cxl =
            s.alloc(&mut hr, MIB, TierPreference::Pinned(MemoryTier::CxlMem), hints()).unwrap();
        let report =
            Transfer::new().fetch(&host, 0).fetch(&cxl, 0).submit(&mut hr).unwrap();
        assert_eq!(report.events[0].src, DeviceId::Host);
        assert_eq!(report.events[1].src, DeviceId::Cxl);
        assert!(
            report.events[1].duration() < report.events[0].duration(),
            "CXL fetch beats PCIe host fetch"
        );
        // host traffic is monitored, demand-attributed, per tier
        assert_eq!(hr.monitor().demand_bytes_on_tier(MemoryTier::Host), MIB);
        assert_eq!(hr.monitor().demand_bytes_on_tier(MemoryTier::CxlMem), MIB);
        s.release(&mut hr, host).unwrap();
        s.release(&mut hr, cxl).unwrap();
    }

    #[test]
    fn migrate_moves_bytes_and_updates_tier() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let lease = s.alloc(&mut hr, 8 * MIB, PEERS, hints()).unwrap();
        assert_eq!(lease.tier(), MemoryTier::PeerHbm(1));
        // demote to host
        let report =
            Transfer::new().migrate(&lease, MemoryTier::Host).submit(&mut hr).unwrap();
        assert_eq!(report.events[0].src, DeviceId::Gpu(1));
        assert_eq!(report.events[0].dst, DeviceId::Host);
        assert_eq!(lease.tier(), MemoryTier::Host, "lease tracks its residency");
        assert_eq!(hr.live_bytes_on(1), 0);
        assert_eq!(hr.live_bytes_on_tier(MemoryTier::Host), 8 * MIB);
        // ledger moves at issue time; the peer segment stays pinned
        // (deferred free) until the in-flight copy completes
        assert_eq!(hr.node.gpus[1].hbm.used(), 8 * MIB);
        assert_eq!(hr.pending_free_bytes_on_tier(MemoryTier::PeerHbm(1)), 8 * MIB);
        assert_eq!(hr.node.host.used(), 8 * MIB);
        // promote back to the peer
        Transfer::new().migrate(&lease, MemoryTier::PeerHbm(1)).submit(&mut hr).unwrap();
        assert_eq!(lease.tier(), MemoryTier::PeerHbm(1));
        assert_eq!(hr.live_bytes_on(1), 8 * MIB);
        assert_eq!(hr.live_bytes_on_tier(MemoryTier::Host), 0);
        assert_eq!(hr.migrations, 2);
        // a same-tier migrate is a no-op
        let report =
            Transfer::new().migrate(&lease, MemoryTier::PeerHbm(1)).submit(&mut hr).unwrap();
        assert!(report.events.is_empty());
        s.release(&mut hr, lease).unwrap();
    }

    #[test]
    fn migrate_is_revocation_safe_and_monitored() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let lease = s.alloc(&mut hr, 16 * MIB, PEERS, hints()).unwrap();
        let before = hr.monitor().prefetch_bytes_on(1);
        // background promotion-style migrate: prefetch-attributed
        let report = Transfer::new()
            .background()
            .migrate(&lease, MemoryTier::Host)
            .submit(&mut hr)
            .unwrap();
        assert!(report.end > hr.node.clock.now(), "migration copy is async");
        assert_eq!(hr.monitor().prefetch_bytes_on(1), before + 16 * MIB);
        assert_eq!(hr.monitor().prefetch_bytes_on_tier(MemoryTier::Host), 16 * MIB);
        // the in-flight migration is lease-tagged: releasing drains it
        s.release(&mut hr, lease).unwrap();
        assert!(hr.node.clock.now() >= report.end, "drain barrier covered the migration");
        assert_eq!(hr.live_bytes_on_tier(MemoryTier::Host), 0);
    }

    #[test]
    fn migrate_to_full_tier_schedules_nothing() {
        let mut hr = rt_cxl();
        let s = HarvestSession::open(&mut hr, PayloadKind::Generic);
        let big = s.alloc(&mut hr, 64 * GIB, PEERS, hints()).unwrap();
        // CXL expander is 64 GiB but a filler lease occupies half
        let filler =
            s.alloc(&mut hr, 32 * GIB, TierPreference::Pinned(MemoryTier::CxlMem), hints())
                .unwrap();
        let err = Transfer::new()
            .migrate(&big, MemoryTier::CxlMem)
            .submit(&mut hr)
            .unwrap_err();
        assert!(matches!(err, HarvestError::NoCapacity { .. }));
        assert_eq!(big.tier(), MemoryTier::PeerHbm(1), "failed migrate changes nothing");
        assert_eq!(hr.live_bytes_on(1), 64 * GIB);
        // a tier whose arena is absent fails cleanly, not at copy time
        let host =
            s.alloc(&mut hr, MIB, TierPreference::Pinned(MemoryTier::Host), hints()).unwrap();
        let mut plain = rt(); // no CXL expander attached
        let s2 = HarvestSession::open(&mut plain, PayloadKind::Generic);
        let host2 =
            s2.alloc(&mut plain, MIB, TierPreference::Pinned(MemoryTier::Host), hints()).unwrap();
        let err =
            Transfer::new().migrate(&host2, MemoryTier::CxlMem).submit(&mut plain).unwrap_err();
        assert_eq!(err, HarvestError::TierUnavailable { tier: MemoryTier::CxlMem });
        assert_eq!(host2.tier(), MemoryTier::Host);
        s2.release(&mut plain, host2).unwrap();
        // host<->CXL share no direct link but the migration stages the
        // copy through a GPU instead of erroring
        let report = Transfer::new().migrate(&host, MemoryTier::CxlMem).submit(&mut hr).unwrap();
        assert_eq!(host.tier(), MemoryTier::CxlMem);
        assert_eq!(report.events[0].src, DeviceId::Host);
        assert_eq!(report.events[0].dst, DeviceId::Cxl);
        s.release(&mut hr, host).unwrap();
        s.release(&mut hr, big).unwrap();
        s.release(&mut hr, filler).unwrap();
    }

    #[test]
    fn chunked_transfer_uses_scattered_descriptors() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let l = s.alloc(&mut hr, 16 * MIB, PEERS, hints()).unwrap();
        let whole =
            Transfer::new().populate(&l, DeviceId::Host).submit(&mut hr).unwrap();
        let l2 = s.alloc(&mut hr, 16 * MIB, PEERS, hints()).unwrap();
        let chunked = Transfer::new()
            .chunked(4 * MIB)
            .populate(&l2, DeviceId::Host)
            .submit(&mut hr)
            .unwrap();
        // scattered descriptors pay per-chunk latency: strictly slower
        assert!(
            chunked.events[0].duration() > whole.events[0].duration(),
            "chunked {} <= contiguous {}",
            chunked.events[0].duration(),
            whole.events[0].duration()
        );
        s.release(&mut hr, l).unwrap();
        s.release(&mut hr, l2).unwrap();
    }

    #[test]
    fn background_transfer_attributed_as_prefetch_but_still_barriered() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let l = s.alloc(&mut hr, 8 * MIB, PEERS, hints()).unwrap();
        let report = Transfer::new()
            .background()
            .populate(&l, DeviceId::Host)
            .fetch(&l, 0)
            .submit(&mut hr)
            .unwrap();
        assert_eq!(report.events.len(), 2);
        // attributed as prefetch, not demand, on the peer
        assert_eq!(hr.monitor().prefetch_bytes_on(1), 16 * MIB);
        assert_eq!(hr.monitor().demand_bytes_on(1), 0);
        // but the §3.2 drain-before-free barrier still covers it: the
        // peer bytes cannot be freed while the background copy reads them
        assert_eq!(hr.node.dma.tag_busy_until(l.id().0), report.end);
        s.release(&mut hr, l).unwrap();
        assert_eq!(
            hr.node.clock.now(),
            report.end,
            "an in-flight background copy is drained before its memory is freed"
        );
    }

    #[test]
    fn compress_then_decompress_via_builder_round_trips() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let l = s.alloc(&mut hr, 32 * MIB, PEERS, hints()).unwrap();
        let report = Transfer::new().compress(&l, 50).submit(&mut hr).unwrap();
        assert_eq!(report.bytes, 0, "compression moves no bytes");
        assert_eq!(hr.live_bytes_on(1), 16 * MIB);
        let info = hr.compression_of(l.id()).expect("compressed");
        assert_eq!(info.ratio, 50);
        assert_eq!(info.original_size, 32 * MIB);
        assert_eq!(
            hr.handle_info(l.id()).unwrap().size,
            16 * MIB,
            "runtime-side size shrank in place"
        );
        // compress → demote → promote → decompress restores the bytes
        Transfer::new()
            .migrate(&l, MemoryTier::Host)
            .migrate(&l, MemoryTier::PeerHbm(1))
            .submit(&mut hr)
            .unwrap();
        assert!(hr.compression_of(l.id()).is_some(), "tag rides along migrations");
        Transfer::new().decompress(&l).submit(&mut hr).unwrap();
        assert!(hr.compression_of(l.id()).is_none());
        assert_eq!(hr.live_bytes_on(1), 32 * MIB);
        assert_eq!(hr.handle_info(l.id()).unwrap().size, 32 * MIB);
        // both ops are idempotent no-ops the second time around
        let report = Transfer::new().decompress(&l).submit(&mut hr).unwrap();
        assert_eq!(report.bytes, 0);
        assert_eq!(hr.compressions, 1);
        s.release(&mut hr, l).unwrap();
    }

    #[test]
    fn empty_transfer_is_a_noop() {
        let mut hr = rt();
        let report = Transfer::new().submit(&mut hr).unwrap();
        assert!(report.events.is_empty());
        assert_eq!(report.bytes, 0);
        assert_eq!(report.end, hr.node.clock.now());
    }
}
