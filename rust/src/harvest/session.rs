//! Lease-based client surface: sessions, RAII leases, and the unified
//! transfer builder.
//!
//! Consumers open one [`HarvestSession`] per subsystem (the KV offload
//! manager, the MoE rebalancer, …) and get:
//!
//! * [`Lease`] — an RAII handle replacing the bare `HandleId`. The
//!   payload kind, durability and client identity ride on the lease;
//!   releasing consumes it (double-free is unrepresentable), and a lease
//!   dropped without release is reclaimed by the runtime's leak sweep,
//!   so `bytes_on` accounting can never drift.
//! * [`HarvestSession::alloc_many`] — vectored, all-or-nothing
//!   allocation for multi-block admission: one policy consultation for
//!   the whole batch, full rollback on partial placement failure.
//! * [`HarvestSession::drain_revocations`] — the pull-model replacement
//!   for `harvest_register_cb`: the controller finishes the whole
//!   revocation pipeline (drain DMA → invalidate → free) before the
//!   event becomes drainable.
//! * [`Transfer`] — one builder for every data movement (`copy_in` and
//!   `fetch_to` unified), with per-lease DMA tagging, optional
//!   scattered-descriptor chunking for paged KV, and a
//!   [`Transfer::background`] mode that attributes a batch as prefetch
//!   bandwidth in the peer monitor.
//!
//! # Example: open → alloc_many → Transfer → release
//!
//! ```
//! use harvest::harvest::{AllocHints, HarvestConfig, HarvestRuntime, PayloadKind, Transfer};
//! use harvest::memsim::{DeviceId, NodeSpec, SimNode};
//!
//! let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()),
//!                                  HarvestConfig::for_node(2));
//! let session = hr.open_session(PayloadKind::KvBlock);
//! let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
//!
//! // Vectored, all-or-nothing: one policy consultation, one peer for
//! // the whole batch, full rollback on failure.
//! let leases = session.alloc_many(&mut hr, &[1 << 20, 1 << 20], hints)?;
//! assert_eq!(leases.len(), 2);
//! assert_eq!(leases[0].peer(), leases[1].peer());
//!
//! // One batched submission: populate both entries, then serve a hit.
//! let report = Transfer::new()
//!     .populate(&leases[0], DeviceId::Host)
//!     .populate(&leases[1], DeviceId::Host)
//!     .fetch(&leases[0], 0)
//!     .submit(&mut hr)?;
//! assert_eq!(report.events.len(), 3);
//! assert_eq!(report.bytes, 3 << 20);
//!
//! // Release consumes each lease — releasing twice does not typecheck.
//! for lease in leases {
//!     session.release(&mut hr, lease)?;
//! }
//! assert_eq!(hr.live_bytes_on(1), 0);
//! # Ok::<(), harvest::harvest::HarvestError>(())
//! ```

use super::api::{AllocHints, HarvestError, HarvestHandle, LeaseId};
use super::controller::HarvestRuntime;
use super::events::{PayloadKind, RevocationEvent};
use crate::memsim::{CopyEvent, DeviceId, Ns};
use std::cell::RefCell;
use std::rc::Rc;

/// Identifier of a session within one runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(pub u32);

/// Shared drop-inbox: leases dropped without an explicit release record
/// their id here; the runtime sweeps it at allocation / pressure / time
/// boundaries and frees whatever is still live.
pub(crate) type ReclaimInbox = Rc<RefCell<Vec<LeaseId>>>;

// ---------------------------------------------------------------------
// Lease
// ---------------------------------------------------------------------

/// RAII ownership of one peer-HBM allocation.
///
/// A `Lease` is not `Clone`/`Copy`: exactly one owner exists, and the
/// only ways it ends are
///
/// 1. [`HarvestSession::release`] — explicit, ordered free (consumes the
///    lease, so releasing twice does not typecheck);
/// 2. revocation by the runtime — the lease object the consumer still
///    holds goes stale, and the session's event queue says so;
/// 3. dropping it — the id lands in the reclaim inbox and the runtime
///    frees the bytes at its next sweep. Leaks are therefore bounded to
///    one sweep interval, never permanent.
#[derive(Debug)]
pub struct Lease {
    handle: HarvestHandle,
    kind: PayloadKind,
    session: SessionId,
    reclaim: ReclaimInbox,
    /// True until released/revoked bookkeeping disarms the drop hook.
    armed: bool,
}

impl Lease {
    pub(crate) fn new(
        handle: HarvestHandle,
        kind: PayloadKind,
        session: SessionId,
        reclaim: ReclaimInbox,
    ) -> Self {
        Self { handle, kind, session, reclaim, armed: true }
    }

    pub fn id(&self) -> LeaseId {
        self.handle.id
    }

    pub fn peer(&self) -> usize {
        self.handle.peer
    }

    pub fn size(&self) -> u64 {
        self.handle.size
    }

    pub fn durability(&self) -> super::api::Durability {
        self.handle.durability
    }

    pub fn client(&self) -> Option<u32> {
        self.handle.client
    }

    pub fn kind(&self) -> PayloadKind {
        self.kind
    }

    pub fn session(&self) -> SessionId {
        self.session
    }

    /// The raw placement record (for metrics / interop with the
    /// deprecated surface).
    pub fn raw(&self) -> HarvestHandle {
        self.handle
    }

    /// Disarm the drop hook and surrender the raw handle. Used by the
    /// release path and by the deprecated shim (which manages lifetime
    /// manually, as the paper's C-style API did).
    pub fn into_raw(mut self) -> HarvestHandle {
        self.armed = false;
        self.handle
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.armed {
            self.reclaim.borrow_mut().push(self.handle.id);
        }
    }
}

// ---------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------

/// A consumer's identity against one [`HarvestRuntime`]: a payload kind,
/// an optional client id for fairness accounting, and a private
/// revocation queue inside the runtime. Copyable — it is just an
/// address; the runtime owns the queue state.
#[derive(Debug, Clone, Copy)]
pub struct HarvestSession {
    id: SessionId,
    kind: PayloadKind,
    client: Option<u32>,
    /// Identity of the runtime this session was opened against. Session
    /// and lease ids are runtime-local, so addressing a *different*
    /// runtime would at best panic on an out-of-range index and at worst
    /// silently drain another consumer's events — checked loudly instead.
    runtime: usize,
}

impl HarvestSession {
    /// Open a session of `kind` against `hr`.
    pub fn open(hr: &mut HarvestRuntime, kind: PayloadKind) -> Self {
        let id = hr.register_session(kind);
        Self { id, kind, client: None, runtime: hr.runtime_tag() }
    }

    /// Open with a client identity; it is stamped onto every allocation
    /// this session makes (unless the hints override it).
    pub fn open_for_client(hr: &mut HarvestRuntime, kind: PayloadKind, client: u32) -> Self {
        let id = hr.register_session(kind);
        Self { id, kind, client: Some(client), runtime: hr.runtime_tag() }
    }

    pub fn id(&self) -> SessionId {
        self.id
    }

    pub fn kind(&self) -> PayloadKind {
        self.kind
    }

    fn check_bound(&self, hr: &HarvestRuntime) {
        assert_eq!(
            self.runtime,
            hr.runtime_tag(),
            "HarvestSession used against a different HarvestRuntime than it was opened on"
        );
    }

    fn effective_hints(&self, hints: AllocHints) -> AllocHints {
        AllocHints { client: hints.client.or(self.client), ..hints }
    }

    /// §3.2 `harvest_alloc`, lease edition: select a peer under the
    /// placement policy and return an RAII lease for the allocation.
    pub fn alloc(
        &self,
        hr: &mut HarvestRuntime,
        size: u64,
        hints: AllocHints,
    ) -> Result<Lease, HarvestError> {
        self.check_bound(hr);
        let handle = hr.alloc_raw(self.id, size, self.effective_hints(hints))?;
        Ok(Lease::new(handle, self.kind, self.id, hr.reclaim_inbox()))
    }

    /// Vectored allocation with all-or-nothing semantics: the placement
    /// policy is consulted once for the aggregate request, every element
    /// lands on the same peer, and a partial placement failure rolls the
    /// whole batch back (no bytes remain allocated, no leases escape).
    pub fn alloc_many(
        &self,
        hr: &mut HarvestRuntime,
        sizes: &[u64],
        hints: AllocHints,
    ) -> Result<Vec<Lease>, HarvestError> {
        self.check_bound(hr);
        let handles = hr.alloc_many_raw(self.id, sizes, self.effective_hints(hints))?;
        let inbox = hr.reclaim_inbox();
        Ok(handles
            .into_iter()
            .map(|h| Lease::new(h, self.kind, self.id, Rc::clone(&inbox)))
            .collect())
    }

    /// §3.2 `harvest_free`, lease edition: ordered, explicit
    /// deallocation (drains DMA tagged with the lease first). Consumes
    /// the lease — double release does not typecheck. No revocation
    /// event is produced: the application initiated the free.
    pub fn release(&self, hr: &mut HarvestRuntime, lease: Lease) -> Result<(), HarvestError> {
        self.check_bound(hr);
        let handle = lease.into_raw();
        hr.free(handle.id)
    }

    /// Drain this session's pending revocation events, oldest first.
    /// Consumers call this at tick boundaries (decode-pass start, KV
    /// manager entry points); every event refers to a lease the runtime
    /// has already drained, invalidated and freed — in that order.
    pub fn drain_revocations(&self, hr: &mut HarvestRuntime) -> Vec<RevocationEvent> {
        self.check_bound(hr);
        hr.drain_session(self.id)
    }

    /// Pending (undrained) event count, without draining.
    pub fn pending_revocations(&self, hr: &HarvestRuntime) -> usize {
        self.check_bound(hr);
        hr.session_queue_len(self.id)
    }

    /// Start a transfer batch (sugar for [`Transfer::new`]).
    pub fn transfer(&self) -> Transfer {
        Transfer::new()
    }
}

// ---------------------------------------------------------------------
// Transfer builder
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum TransferOp {
    /// Populate the peer cache: `src` → the lease's peer allocation.
    Populate { lease: LeaseId, src: DeviceId },
    /// Serve a hit: the lease's peer allocation → the compute GPU.
    Fetch { lease: LeaseId, compute: usize },
    /// An untagged raw move (host spill path, durable host copies).
    Raw { src: DeviceId, dst: DeviceId, bytes: u64 },
}

/// Report of one submitted transfer batch.
#[derive(Debug, Clone, Default)]
pub struct TransferReport {
    /// One entry per op, in submission order.
    pub events: Vec<CopyEvent>,
    /// Total bytes moved.
    pub bytes: u64,
    /// Completion time of the batch (max op end; current virtual time if
    /// the batch was empty).
    pub end: Ns,
}

impl TransferReport {
    /// Completion of the last submitted op (panics on empty batches).
    pub fn last(&self) -> &CopyEvent {
        self.events.last().expect("non-empty transfer batch")
    }
}

/// Batched-DMA builder unifying the old `copy_in` / `fetch_to` pair.
///
/// Ops accumulate, then [`Transfer::submit`] schedules them in order on
/// the simulated DMA engine. Lease-addressed ops are tagged with the
/// lease id, so the revocation pipeline's drain-by-tag covers them; raw
/// ops are untagged. `chunked(n)` batches every op into scattered
/// descriptors of at most `n` bytes (paged-KV reload granularity).
#[derive(Debug, Default)]
pub struct Transfer {
    ops: Vec<TransferOp>,
    chunk_bytes: Option<u64>,
    background: bool,
}

impl Transfer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Split every op into scattered DMA descriptors of at most
    /// `descriptor_bytes` (e.g. [`crate::kv::manager::RELOAD_CHUNK_BYTES`]).
    pub fn chunked(mut self, descriptor_bytes: u64) -> Self {
        assert!(descriptor_bytes > 0, "descriptor size must be positive");
        self.chunk_bytes = Some(descriptor_bytes);
        self
    }

    /// Mark this batch as *background* (prefetch) traffic: its peer
    /// traffic is recorded as prefetch bandwidth in the
    /// [`super::monitor::PeerMonitor`] — still visible to the
    /// interference policy, but attributed separately from demand
    /// traffic. Background ops keep their lease tags, so the §3.2
    /// drain-before-free barrier covers them exactly like demand DMA; to
    /// keep that barrier off the hot path, consumers defer the lease
    /// release until the background copy has completed (see
    /// [`crate::kv::manager::KvOffloadManager::submit_prefetch`]).
    pub fn background(mut self) -> Self {
        self.background = true;
        self
    }

    /// Queue a populate: copy `lease.size()` bytes from `src` into the
    /// lease's peer allocation (the old `copy_in`).
    pub fn populate(mut self, lease: &Lease, src: DeviceId) -> Self {
        self.ops.push(TransferOp::Populate { lease: lease.id(), src });
        self
    }

    /// Queue a fetch: copy the lease's bytes from its peer to
    /// `compute_gpu` (the old `fetch_to` — the fast path the paper
    /// measures).
    pub fn fetch(mut self, lease: &Lease, compute_gpu: usize) -> Self {
        self.ops.push(TransferOp::Fetch { lease: lease.id(), compute: compute_gpu });
        self
    }

    /// Queue an untagged raw move between arbitrary devices (host
    /// spills, durable host copies).
    pub fn raw(mut self, src: DeviceId, dst: DeviceId, bytes: u64) -> Self {
        self.ops.push(TransferOp::Raw { src, dst, bytes });
        self
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Schedule every queued op, in order. Fails with
    /// [`HarvestError::StaleLease`] (scheduling nothing at all) if any
    /// lease-addressed op names a lease that is no longer live — check
    /// ordering is all-or-nothing so a half-submitted batch cannot
    /// occur.
    pub fn submit(self, hr: &mut HarvestRuntime) -> Result<TransferReport, HarvestError> {
        // Validate every lease op before scheduling anything.
        let mut resolved: Vec<(DeviceId, DeviceId, u64, Option<u64>, Option<usize>)> =
            Vec::with_capacity(self.ops.len());
        for op in &self.ops {
            match *op {
                TransferOp::Populate { lease, src } => {
                    let h = hr.handle_info(lease).ok_or(HarvestError::StaleLease(lease))?;
                    resolved
                        .push((src, DeviceId::Gpu(h.peer), h.size, Some(lease.0), Some(h.peer)));
                }
                TransferOp::Fetch { lease, compute } => {
                    let h = hr.handle_info(lease).ok_or(HarvestError::StaleLease(lease))?;
                    resolved.push((
                        DeviceId::Gpu(h.peer),
                        DeviceId::Gpu(compute),
                        h.size,
                        Some(lease.0),
                        Some(h.peer),
                    ));
                }
                TransferOp::Raw { src, dst, bytes } => {
                    resolved.push((src, dst, bytes, None, None));
                }
            }
        }
        let mut report =
            TransferReport { events: Vec::with_capacity(resolved.len()), bytes: 0, end: 0 };
        for (src, dst, bytes, tag, peer) in resolved {
            let ev = match self.chunk_bytes {
                Some(chunk) if bytes > chunk => {
                    let n_chunks = bytes.div_ceil(chunk);
                    hr.node.copy_scattered(src, dst, bytes, n_chunks, tag)
                }
                _ => hr.node.copy(src, dst, bytes, tag),
            };
            if let Some(p) = peer {
                if self.background {
                    hr.record_peer_prefetch(p, ev.end, bytes);
                } else {
                    hr.record_peer_transfer(p, ev.end, bytes);
                }
            }
            report.bytes += bytes;
            report.end = report.end.max(ev.end);
            report.events.push(ev);
        }
        if report.events.is_empty() {
            report.end = hr.node.clock.now();
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harvest::api::Durability;
    use crate::harvest::controller::HarvestConfig;
    use crate::memsim::{NodeSpec, SimNode};

    const MIB: u64 = 1 << 20;
    const GIB: u64 = 1 << 30;

    fn rt() -> HarvestRuntime {
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
    }

    fn hints() -> AllocHints {
        AllocHints { compute_gpu: Some(0), ..Default::default() }
    }

    #[test]
    fn lease_carries_typed_metadata() {
        let mut hr = rt();
        let s = HarvestSession::open_for_client(&mut hr, PayloadKind::KvBlock, 7);
        let lease = s
            .alloc(&mut hr, 2 * MIB, AllocHints { durability: Durability::Lossy, ..hints() })
            .unwrap();
        assert_eq!(lease.kind(), PayloadKind::KvBlock);
        assert_eq!(lease.durability(), Durability::Lossy);
        assert_eq!(lease.client(), Some(7), "session client stamped onto the lease");
        assert_eq!(lease.peer(), 1);
        assert_eq!(lease.size(), 2 * MIB);
        s.release(&mut hr, lease).unwrap();
        assert_eq!(hr.live_bytes_on(1), 0);
    }

    #[test]
    fn dropped_lease_is_reclaimed_by_sweep() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::Generic);
        let lease = s.alloc(&mut hr, 4 * MIB, hints()).unwrap();
        let id = lease.id();
        drop(lease); // leaked, not released
        assert!(hr.is_live(id), "not yet swept");
        assert_eq!(hr.sweep_leaked(), 1);
        assert!(!hr.is_live(id));
        assert_eq!(hr.live_bytes_on(1), 0);
        assert_eq!(hr.node.gpus[1].hbm.used(), 0);
        // no revocation event: the app dropped it, nothing to repair
        assert!(s.drain_revocations(&mut hr).is_empty());
    }

    #[test]
    fn release_consumes_and_revoked_lease_is_stale() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::Generic);
        let lease = s.alloc(&mut hr, MIB, hints()).unwrap();
        let id = lease.id();
        s.release(&mut hr, lease).unwrap();
        // `lease` is moved — releasing again does not compile. The raw id
        // is stale:
        assert_eq!(hr.free(id), Err(HarvestError::StaleLease(id)));
        // a revoked lease's transfers fail closed
        let lease2 = s.alloc(&mut hr, MIB, hints()).unwrap();
        hr.revoke(lease2.id(), crate::harvest::api::RevocationReason::PolicyEviction);
        let err = Transfer::new().fetch(&lease2, 0).submit(&mut hr).unwrap_err();
        assert_eq!(err, HarvestError::StaleLease(lease2.id()));
    }

    #[test]
    fn alloc_many_is_all_or_nothing() {
        let mut hr = rt();
        // cap the only peer at 3 GiB
        hr.config.mig[1] = crate::harvest::MigConfig::CachePartition { bytes: 3 * GIB };
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        // 2 GiB fits...
        let got = s.alloc_many(&mut hr, &[GIB, GIB], hints()).unwrap();
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|l| l.peer() == 1), "one peer for the whole batch");
        assert_eq!(hr.live_bytes_on(1), 2 * GIB);
        for l in got {
            s.release(&mut hr, l).unwrap();
        }
        // ...4 GiB does not: nothing must stick
        let before_fail = hr.alloc_failures;
        let err = s.alloc_many(&mut hr, &[GIB, GIB, GIB, GIB], hints()).unwrap_err();
        assert!(matches!(err, HarvestError::NoCapacity { requested } if requested == 4 * GIB));
        assert_eq!(hr.live_bytes_on(1), 0, "rollback left no bytes");
        assert_eq!(hr.node.gpus[1].hbm.used(), 0);
        assert!(hr.alloc_failures > before_fail);
    }

    #[test]
    fn alloc_many_rejects_zero_and_accepts_empty() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::Generic);
        assert!(s.alloc_many(&mut hr, &[], hints()).unwrap().is_empty());
        assert_eq!(s.alloc_many(&mut hr, &[MIB, 0], hints()).unwrap_err(), HarvestError::ZeroSize);
        assert_eq!(hr.live_bytes_on(1), 0);
    }

    #[test]
    fn transfer_builder_orders_and_tags() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::ExpertWeights);
        let a = s.alloc(&mut hr, 32 * MIB, hints()).unwrap();
        let b = s.alloc(&mut hr, 32 * MIB, hints()).unwrap();
        let report = Transfer::new()
            .populate(&a, DeviceId::Host)
            .populate(&b, DeviceId::Host)
            .fetch(&a, 0)
            .submit(&mut hr)
            .unwrap();
        assert_eq!(report.events.len(), 3);
        assert_eq!(report.bytes, 96 * MIB);
        assert_eq!(report.events[2].src, DeviceId::Gpu(1));
        assert_eq!(report.events[2].dst, DeviceId::Gpu(0));
        assert!(report.end >= report.events[2].end);
        // per-lease tagging: draining lease a's tag waits for its ops
        let drained = hr.node.dma.drain_tag(&hr.node.topo, a.id().0);
        assert!(drained >= report.events[2].end);
        s.release(&mut hr, a).unwrap();
        s.release(&mut hr, b).unwrap();
    }

    #[test]
    fn chunked_transfer_uses_scattered_descriptors() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let l = s.alloc(&mut hr, 16 * MIB, hints()).unwrap();
        let whole =
            Transfer::new().populate(&l, DeviceId::Host).submit(&mut hr).unwrap();
        let l2 = s.alloc(&mut hr, 16 * MIB, hints()).unwrap();
        let chunked = Transfer::new()
            .chunked(4 * MIB)
            .populate(&l2, DeviceId::Host)
            .submit(&mut hr)
            .unwrap();
        // scattered descriptors pay per-chunk latency: strictly slower
        assert!(
            chunked.events[0].duration() > whole.events[0].duration(),
            "chunked {} <= contiguous {}",
            chunked.events[0].duration(),
            whole.events[0].duration()
        );
        s.release(&mut hr, l).unwrap();
        s.release(&mut hr, l2).unwrap();
    }

    #[test]
    fn background_transfer_attributed_as_prefetch_but_still_barriered() {
        let mut hr = rt();
        let s = HarvestSession::open(&mut hr, PayloadKind::KvBlock);
        let l = s.alloc(&mut hr, 8 * MIB, hints()).unwrap();
        let report = Transfer::new()
            .background()
            .populate(&l, DeviceId::Host)
            .fetch(&l, 0)
            .submit(&mut hr)
            .unwrap();
        assert_eq!(report.events.len(), 2);
        // attributed as prefetch, not demand, on the peer
        assert_eq!(hr.monitor().prefetch_bytes_on(1), 16 * MIB);
        assert_eq!(hr.monitor().demand_bytes_on(1), 0);
        // but the §3.2 drain-before-free barrier still covers it: the
        // peer bytes cannot be freed while the background copy reads them
        assert_eq!(hr.node.dma.tag_busy_until(l.id().0), report.end);
        s.release(&mut hr, l).unwrap();
        assert_eq!(
            hr.node.clock.now(),
            report.end,
            "an in-flight background copy is drained before its memory is freed"
        );
    }

    #[test]
    fn empty_transfer_is_a_noop() {
        let mut hr = rt();
        let report = Transfer::new().submit(&mut hr).unwrap();
        assert!(report.events.is_empty());
        assert_eq!(report.bytes, 0);
        assert_eq!(report.end, hr.node.clock.now());
    }
}
