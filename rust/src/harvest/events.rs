//! Pull-model revocation events (§3.2, redesigned).
//!
//! The paper sketches `harvest_register_cb(handle, cb)` — a push
//! callback fired inside the revocation pipeline. Push callbacks force
//! every consumer to share mutable state with the runtime
//! (`Rc<RefCell<…>>` in a single-threaded build, locks in a threaded
//! one) and make the drain → invalidate → notify ordering invisible to
//! the application. The redesigned surface is *pull*: each
//! [`crate::harvest::session::HarvestSession`] owns a
//! [`RevocationQueue`] inside the runtime; the controller completes the
//! whole pipeline (drain in-flight DMA, invalidate the placement, free
//! the arena bytes — or, for a demotion, migrate the bytes to a slower
//! tier) **before** enqueueing the event, and the consumer drains its
//! queue at a tick boundary of its choosing via `drain_revocations`.
//!
//! Each event carries a [`RevocationAction`]: under
//! [`RevocationAction::Dropped`] the lease it names is guaranteed dead
//! by the time the event is drainable; under
//! [`RevocationAction::Demoted`] the lease *survives* on the slower
//! tier it was migrated to (peer → host under pressure), and only the
//! fast-tier placement is gone.
//!
//! # The drain ordering guarantee
//!
//! Events are delivered FIFO in pipeline-completion order, exactly once,
//! and only after invalidation:
//!
//! ```
//! use harvest::harvest::{AllocHints, HarvestConfig, HarvestRuntime, PayloadKind,
//!                        RevocationAction, RevocationReason, TierPreference};
//! use harvest::memsim::{NodeSpec, SimNode};
//!
//! let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()),
//!                                  HarvestConfig::for_node(2));
//! let session = hr.open_session(PayloadKind::Generic);
//! let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
//! let a = session.alloc(&mut hr, 1 << 20, TierPreference::PEER_ONLY, hints)?;
//! let b = session.alloc(&mut hr, 1 << 20, TierPreference::PEER_ONLY, hints)?;
//!
//! assert!(hr.revoke(a.id(), RevocationReason::TenantPressure).is_some());
//! assert!(hr.revoke(b.id(), RevocationReason::PolicyEviction).is_some());
//!
//! // By the time the events are drainable, both leases are already dead
//! // (drain-DMA → invalidate → free completed first)...
//! assert!(!hr.is_live(a.id()) && !hr.is_live(b.id()));
//! let events = session.drain_revocations(&mut hr);
//! // ...and they arrive oldest first, exactly once, each carrying the
//! // tier they were revoked from and what happened to the payload.
//! assert_eq!(events.len(), 2);
//! assert_eq!(events[0].lease, a.id());
//! assert_eq!(events[1].lease, b.id());
//! assert!(events.iter().all(|e| e.action == RevocationAction::Dropped));
//! assert!(events.iter().all(|e| e.tier.is_peer()));
//! assert!(events[0].at <= events[1].at);
//! assert!(session.drain_revocations(&mut hr).is_empty());
//! # drop((a, b)); // stale RAII owners; the runtime's sweep ignores them
//! # Ok::<(), harvest::harvest::HarvestError>(())
//! ```

use super::api::{Durability, LeaseId, MemoryTier, RevocationReason};
use crate::memsim::Ns;
use std::collections::VecDeque;

/// What kind of payload a lease (and therefore its revocation event)
/// carries. Typed so a consumer that multiplexes payloads can route
/// events without a side table, and so metrics can attribute revocations
/// per tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PayloadKind {
    /// MoE expert weights (host-backed cache entries, §4.3).
    ExpertWeights,
    /// Paged KV-cache blocks (lossy cache entries, §5.2).
    KvBlock,
    /// Anything else (examples, benches, the deprecated shim surface).
    #[default]
    Generic,
}

impl PayloadKind {
    pub fn name(&self) -> &'static str {
        match self {
            PayloadKind::ExpertWeights => "expert-weights",
            PayloadKind::KvBlock => "kv-block",
            PayloadKind::Generic => "generic",
        }
    }
}

/// What the revocation pipeline did with the payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RevocationAction {
    /// The lease is dead and its bytes are gone; the consumer repairs
    /// its indexes (host fallback or reconstruct, per durability).
    Dropped,
    /// The lease *survived*: pressure evicted it from its fast tier but
    /// the controller migrated the bytes to `to` (peer → host demotion)
    /// instead of dropping them. The lease now reads from `to`; no data
    /// was lost, only latency.
    Demoted { to: MemoryTier },
    /// The lease *survived in place*: pressure shrank it to
    /// `ratio` percent of its original size via modeled layer-wise KV
    /// compression instead of migrating or dropping it. The lease still
    /// reads from its original tier; the consumer must charge the
    /// modeled decompression cost when it next reloads the payload.
    Compressed { ratio: u32 },
}

/// One completed revocation as observed by the owning session. Unlike
/// the legacy [`crate::harvest::api::Revocation`] it does not carry a
/// live `HarvestHandle` — the fast-tier placement it describes is
/// already gone — only the facts a consumer needs to repair its own
/// indexes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RevocationEvent {
    /// The revoked lease. Under [`RevocationAction::Dropped`] it is
    /// guaranteed dead (not live in the runtime) by the time the event
    /// can be drained; under [`RevocationAction::Demoted`] it is still
    /// live, resident on the demotion target tier.
    pub lease: LeaseId,
    /// Payload kind the owning session declared at `open`.
    pub kind: PayloadKind,
    /// Tier the bytes were revoked from.
    pub tier: MemoryTier,
    /// Size of the revoked allocation.
    pub size: u64,
    /// Durability the lease was allocated with — tells the consumer
    /// which fallback is legal (host copy vs reconstruct).
    pub durability: Durability,
    /// Client identity from the allocation hints, if any.
    pub client: Option<u32>,
    pub reason: RevocationReason,
    /// What happened to the payload: dropped, or demoted to a slower
    /// tier with the lease intact.
    pub action: RevocationAction,
    /// Virtual time at which the pipeline completed (after the DMA
    /// drain; for demotions, when the demotion copy was issued).
    pub at: Ns,
}

/// A session's drainable event queue. FIFO: events are observed in
/// exactly the order the controller completed them.
#[derive(Debug, Default)]
pub struct RevocationQueue {
    events: VecDeque<RevocationEvent>,
    /// Total events ever enqueued (drained or not), for metrics.
    enqueued: u64,
    /// High-water mark of undrained depth — a consumer that lets this
    /// grow is draining too rarely.
    peak_depth: usize,
}

impl RevocationQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, ev: RevocationEvent) {
        self.events.push_back(ev);
        self.enqueued += 1;
        self.peak_depth = self.peak_depth.max(self.events.len());
    }

    /// Take every pending event, oldest first.
    pub fn drain(&mut self) -> Vec<RevocationEvent> {
        self.events.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn total_enqueued(&self) -> u64 {
        self.enqueued
    }

    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, at: Ns) -> RevocationEvent {
        RevocationEvent {
            lease: LeaseId(id),
            kind: PayloadKind::Generic,
            tier: MemoryTier::PeerHbm(1),
            size: 64,
            durability: Durability::Lossy,
            client: None,
            reason: RevocationReason::TenantPressure,
            action: RevocationAction::Dropped,
            at,
        }
    }

    #[test]
    fn drain_preserves_fifo_order() {
        let mut q = RevocationQueue::new();
        q.push(ev(1, 10));
        q.push(ev(2, 20));
        q.push(ev(3, 30));
        let got = q.drain();
        assert_eq!(got.iter().map(|e| e.lease.0).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(q.is_empty());
        assert_eq!(q.drain(), Vec::new());
    }

    #[test]
    fn counters_track_enqueues_and_depth() {
        let mut q = RevocationQueue::new();
        q.push(ev(1, 1));
        q.push(ev(2, 2));
        assert_eq!(q.len(), 2);
        q.drain();
        q.push(ev(3, 3));
        assert_eq!(q.total_enqueued(), 3);
        assert_eq!(q.peak_depth(), 2);
        assert_eq!(q.len(), 1);
    }
}
