//! The Harvest runtime — the paper's system contribution (§3).
//!
//! Harvest exposes unused HBM on *peer GPUs* as a best-effort, revocable
//! cache tier through three core operations (§3.2):
//!
//! ```text
//! harvest_alloc(size, hints) -> handle
//! harvest_free(handle)
//! harvest_register_cb(handle, cb)
//! ```
//!
//! * [`api`] — handles, hints, durability modes, revocation reasons.
//! * [`policy`] — pluggable placement policies: best-fit (the paper's
//!   default) plus the locality / fairness / interference / stability
//!   variants §3.2 sketches.
//! * [`monitor`] — peer-availability views (free capacity, churn,
//!   bandwidth demand) that policies consult.
//! * [`controller`] — the runtime: performs allocations on the selected
//!   peer, watches tenant pressure, and drives the revocation pipeline
//!   (drain in-flight DMA → invalidate placement → fire callback) in
//!   exactly that order.
//! * [`mig`] — MIG-style isolation: harvesting confined to a reserved
//!   capacity partition per peer GPU.
//!
//! Correctness never depends on the peer tier: every cached object is
//! either [`api::Durability::HostBacked`] or
//! [`api::Durability::Lossy`] (reconstructible), and the runtime never
//! tracks dirty state or performs write-back (§3.1).

pub mod api;
pub mod controller;
pub mod mig;
pub mod monitor;
pub mod policy;

pub use api::{AllocHints, Durability, HandleId, HarvestError, HarvestHandle, Revocation,
              RevocationReason};
pub use controller::{HarvestConfig, HarvestRuntime, VictimPolicy};
pub use mig::MigConfig;
pub use monitor::{PeerMonitor, PeerView};
pub use policy::{BestFit, FirstAvailable, InterferenceAware, LocalityAware, PlacementPolicy,
                 RateLimitFairness, StabilityAware};
