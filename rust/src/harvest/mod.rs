//! The Harvest runtime — the paper's system contribution (§3), behind a
//! lease-based client API.
//!
//! Harvest exposes unused HBM on *peer GPUs* as a best-effort, revocable
//! cache tier. The paper sketches a C-style surface (§3.2):
//!
//! ```text
//! harvest_alloc(size, hints) -> handle
//! harvest_free(handle)
//! harvest_register_cb(handle, cb)
//! ```
//!
//! This crate redesigns it around revocable **leases** with pull-model
//! revocation events:
//!
//! ```text
//! let session = hr.open_session(PayloadKind::KvBlock);
//! let lease   = session.alloc(&mut hr, size, hints)?;          // RAII
//! let batch   = session.alloc_many(&mut hr, &sizes, hints)?;   // all-or-nothing
//! Transfer::new().populate(&lease, src).fetch(&lease, gpu).submit(&mut hr)?;
//! session.release(&mut hr, lease)?;                            // consumes: no double free
//! for ev in session.drain_revocations(&mut hr) { /* repair indexes */ }
//! ```
//!
//! * [`session`] — [`session::HarvestSession`] (per-consumer identity +
//!   private event queue), [`session::Lease`] (RAII: leaked leases are
//!   swept, double-free does not typecheck), and the
//!   [`session::Transfer`] builder unifying populate/fetch/raw moves in
//!   one batched-DMA path with per-lease tagging.
//! * [`events`] — [`events::PayloadKind`], [`events::RevocationEvent`]
//!   and the drainable [`events::RevocationQueue`]. The controller
//!   completes drain-DMA → invalidate → free **before** an event becomes
//!   observable, so consumers repair their indexes at tick boundaries
//!   with no shared mutable state.
//! * [`api`] — ids, hints, durability modes, revocation reasons, errors.
//! * [`policy`] — pluggable placement policies: best-fit (the paper's
//!   default) plus the locality / fairness / interference / stability
//!   variants §3.2 sketches. Vectored batches consult the policy once.
//! * [`monitor`] — peer-availability views (free capacity, churn,
//!   bandwidth demand — demand and prefetch traffic attributed
//!   separately) that policies consult.
//! * [`prefetch`] — the deadline-aware prefetch planner: admission
//!   control that lets consumers overlap peer DMA with decode compute
//!   without ever delaying a demand fetch, plus the hit/late/waste
//!   outcome ledger.
//! * [`controller`] — the runtime: performs allocations on the selected
//!   peer, watches tenant pressure, drives the revocation pipeline, and
//!   keeps the paper's raw surface alive as deprecated shims.
//! * [`mig`] — MIG-style isolation: harvesting confined to a reserved
//!   capacity partition per peer GPU.
//!
//! Correctness never depends on the peer tier: every cached object is
//! either [`api::Durability::HostBacked`] or
//! [`api::Durability::Lossy`] (reconstructible), and the runtime never
//! tracks dirty state or performs write-back (§3.1).

pub mod api;
pub mod controller;
pub mod events;
pub mod mig;
pub mod monitor;
pub mod policy;
pub mod prefetch;
pub mod session;

pub use api::{AllocHints, Durability, HarvestError, HarvestHandle, LeaseId, Revocation,
              RevocationReason};
#[allow(deprecated)] // re-exported so pre-lease call sites keep compiling
pub use api::HandleId;
pub use controller::{HarvestConfig, HarvestRuntime, VictimPolicy};
pub use events::{PayloadKind, RevocationEvent, RevocationQueue};
pub use mig::MigConfig;
pub use monitor::{PeerMonitor, PeerView};
pub use policy::{BestFit, FirstAvailable, InterferenceAware, LocalityAware, PlacementPolicy,
                 RateLimitFairness, StabilityAware};
pub use prefetch::{PrefetchConfig, PrefetchPlanner, PrefetchStats};
pub use session::{HarvestSession, Lease, SessionId, Transfer, TransferReport};
