//! The Harvest runtime — the paper's system contribution (§3), behind a
//! tier-aware, lease-based client API.
//!
//! Harvest exposes unused HBM on *peer GPUs* as a best-effort, revocable
//! cache tier. The paper sketches a C-style surface (§3.2):
//!
//! ```text
//! harvest_alloc(size, hints) -> handle
//! harvest_free(handle)
//! harvest_register_cb(handle, cb)
//! ```
//!
//! This crate redesigns it around revocable **leases** on an explicit
//! **memory-tier hierarchy** (`LocalHbm` / `PeerHbm(gpu)` / `CxlMem` /
//! `Host`), with pull-model revocation events:
//!
//! ```text
//! let session = hr.open_session(PayloadKind::KvBlock);
//! let lease   = session.alloc(&mut hr, size, TierPreference::FastestAvailable, hints)?;
//! let batch   = session.alloc_many(&mut hr, &sizes, pref, hints)?;  // all-or-nothing
//! Transfer::new().populate(&lease, src).fetch(&lease, gpu).submit(&mut hr)?;
//! Transfer::new().migrate(&lease, MemoryTier::Host).submit(&mut hr)?; // demote/promote
//! session.release(&mut hr, lease)?;                             // consumes: no double free
//! for ev in session.drain_revocations(&mut hr) { /* repair indexes */ }
//! ```
//!
//! * [`api`] — [`api::MemoryTier`] and [`api::TierPreference`] (the
//!   hierarchy and what slice of it an allocation accepts), ids, hints,
//!   durability modes, revocation reasons, errors.
//! * [`session`] — [`session::HarvestSession`] (per-consumer identity +
//!   private event queue), [`session::Lease`] (RAII, carries its
//!   resident tier across migrations; leaked leases are swept,
//!   double-free does not typecheck), and the [`session::Transfer`]
//!   builder unifying populate/fetch/raw/migrate moves in one
//!   batched-DMA path with per-lease tagging.
//! * [`events`] — [`events::PayloadKind`], [`events::RevocationEvent`]
//!   with its [`events::RevocationAction`] (`Dropped` vs `Demoted`), and
//!   the drainable [`events::RevocationQueue`]. The controller completes
//!   drain-DMA → invalidate → free (or the demotion migration) **before**
//!   an event becomes observable, so consumers repair their indexes at
//!   tick boundaries with no shared mutable state.
//! * [`policy`] — pluggable placement policies: best-fit (the paper's
//!   default) plus the locality / fairness / interference / stability
//!   variants §3.2 sketches, each extended to the cross-tier decision by
//!   [`policy::PlacementPolicy::place_tiered`] — peer HBM, host DRAM and
//!   CXL scored under one cost model (capacity, link queue,
//!   interference). Vectored batches consult the policy once.
//! * [`monitor`] — per-tier availability views (free capacity, churn,
//!   bandwidth demand — demand and prefetch traffic attributed
//!   separately on every tier slot) that policies consult.
//! * [`prefetch`] — the deadline-aware prefetch planner: admission
//!   control that lets consumers overlap tier DMA (peer reloads *and*
//!   host→peer promotions) with decode compute without ever delaying a
//!   demand fetch, plus the hit/late/waste outcome ledger.
//! * [`controller`] — the runtime: performs allocations on the selected
//!   tier, watches tenant pressure (optionally demoting lossy leases to
//!   host instead of dropping them), drives the revocation pipeline, and
//!   keeps the paper's raw surface alive as deprecated shims.
//! * [`mig`] — MIG-style isolation: harvesting confined to a reserved
//!   capacity partition per peer GPU.
//!
//! Correctness never depends on the fast tiers: every cached object is
//! either [`api::Durability::HostBacked`] or
//! [`api::Durability::Lossy`] (reconstructible), and the runtime never
//! tracks dirty state or performs write-back (§3.1).

pub mod api;
pub mod controller;
pub mod events;
pub mod mig;
pub mod monitor;
pub mod policy;
pub mod prefetch;
pub mod session;

pub use api::{AllocHints, Durability, HarvestError, HarvestHandle, LeaseId, MemoryTier,
              Revocation, RevocationReason, TierPreference};
#[allow(deprecated)] // re-exported so pre-lease call sites keep compiling
pub use api::HandleId;
pub use controller::{CompressionInfo, HarvestConfig, HarvestRuntime, VictimPolicy};
pub use events::{PayloadKind, RevocationAction, RevocationEvent, RevocationQueue};
pub use mig::MigConfig;
pub use monitor::{PeerMonitor, PeerView};
pub use policy::{BestFit, FirstAvailable, InterferenceAware, LocalityAware, PlacementPolicy,
                 PlacementSpec, RateLimitFairness, StabilityAware, TierView,
                 TieredPlacementRequest};
pub use prefetch::{PrefetchConfig, PrefetchPlanner, PrefetchStats};
pub use session::{HarvestSession, Lease, SessionId, Transfer, TransferReport};
