//! A whole simulated server node: GPUs (HBM + tenant load) + host DRAM +
//! optional CXL memory + link topology + DMA engine + virtual clock,
//! wired together.
//!
//! This is the object the Harvest runtime, the MoE pipeline and the KV
//! manager all share. It corresponds to the paper's testbed (an Azure
//! NC80adis H100 v5: 2× H100 80 GB, PCIe 5.0, 12 NVLink links) by
//! default, but node shape is fully configurable — DESIGN.md's §7
//! limitations call out larger NVLink domains, and `NodeSpec::n_gpus`
//! lets benches explore them. Host DRAM and CXL-attached memory are
//! allocatable arenas like the GPUs' HBM, so the tier-aware harvest
//! controller can account host/CXL leases exactly like peer ones.

use super::clock::{Clock, Ns};
use super::dma::{DmaEngine, StreamId};
use super::hbm::{FitStrategy, Hbm};
use super::interconnect::{DeviceId, FabricKind, LinkModel, Topology};
use super::tenant::TenantLoad;

const GIB: u64 = 1 << 30;

/// Static description of one GPU.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub hbm_bytes: u64,
    pub fit: FitStrategy,
}

impl Default for GpuSpec {
    fn default() -> Self {
        Self { hbm_bytes: 80 * GIB, fit: FitStrategy::BestFit }
    }
}

/// Static description of the node.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub gpus: Vec<GpuSpec>,
    pub nvlink: LinkModel,
    pub pcie: LinkModel,
    /// GPU↔GPU wiring (§2.2 larger NVLink domains / §8 topology).
    pub fabric: FabricKind,
    /// Host DRAM capacity (the testbed carries 1.9 TB; we model a round
    /// 1 TiB — effectively unconstrained next to 80 GiB HBM).
    pub host_dram_bytes: u64,
    /// CXL memory-expander capacity. 0 = tier absent (the default — the
    /// paper's testbed has none); enable with [`NodeSpec::with_cxl`].
    pub cxl_bytes: u64,
    /// NVMe SSD arena capacity (the cold-tier ladder's last rung).
    /// 0 = tier absent (the default); enable with [`NodeSpec::with_ssd`].
    pub ssd_bytes: u64,
}

impl Default for NodeSpec {
    fn default() -> Self {
        Self::h100x2()
    }
}

impl NodeSpec {
    /// The paper's testbed: 2× H100 80 GB.
    pub fn h100x2() -> Self {
        Self {
            gpus: vec![GpuSpec::default(), GpuSpec::default()],
            nvlink: LinkModel::nvlink_h100(),
            pcie: LinkModel::pcie5_host(),
            fabric: FabricKind::FullMesh,
            host_dram_bytes: 1024 * GIB,
            cxl_bytes: 0,
            ssd_bytes: 0,
        }
    }

    /// An `n`-GPU NVLink/NVSwitch domain (future-deployment sweeps).
    pub fn nvlink_domain(n: usize) -> Self {
        Self { gpus: vec![GpuSpec::default(); n], ..Self::h100x2() }
    }

    /// Same, wired through an NVSwitch (NVL72-class racks).
    pub fn nvswitch_domain(n: usize) -> Self {
        Self { fabric: FabricKind::NvSwitch, ..Self::nvlink_domain(n) }
    }

    /// Cost-reduced ring fabric.
    pub fn ring_domain(n: usize) -> Self {
        Self { fabric: FabricKind::Ring, ..Self::nvlink_domain(n) }
    }

    /// Host tier's link replaced by CXL-attached memory characteristics
    /// (§8). Distinct from [`NodeSpec::with_cxl`], which adds a separate
    /// CXL arena *alongside* host DRAM.
    pub fn with_cxl_host(mut self) -> Self {
        self.pcie = LinkModel::cxl_mem();
        self
    }

    /// Attach a CXL memory expander of `bytes`, making [`DeviceId::Cxl`]
    /// an allocatable tier between peer HBM and host DRAM.
    pub fn with_cxl(mut self, bytes: u64) -> Self {
        self.cxl_bytes = bytes;
        self
    }

    /// Attach an NVMe SSD arena of `bytes`, making [`DeviceId::Ssd`] an
    /// allocatable cold tier behind the host bridge.
    pub fn with_ssd(mut self, bytes: u64) -> Self {
        self.ssd_bytes = bytes;
        self
    }
}

/// One simulated GPU: its HBM arena plus the co-tenant load.
///
/// Co-tenant usage has two sources that coexist:
///
/// * `tenant` — the exogenous *timeline* (replay mode: pre-generated
///   pressure that occupies no real arena segments);
/// * `tenant_held` — bytes tenant **actors**
///   ([`crate::tenantsim`]) hold as real segments *inside* `hbm`, so
///   they genuinely fragment the arena. Maintained by the
///   [`crate::tenantsim::PressureBroker`].
///
/// Total co-tenant usage at time `t` is [`Gpu::tenant_used_at`]; the
/// harvest controller's own bytes on this GPU are
/// `hbm.used() - tenant_held` (minus any deferred migration-source
/// frees it tracks itself).
#[derive(Debug)]
pub struct Gpu {
    pub hbm: Hbm,
    pub tenant: TenantLoad,
    /// Bytes of real `hbm` segments held by tenant actors.
    pub tenant_held: u64,
}

impl Gpu {
    /// Combined co-tenant usage at `t`: the exogenous timeline plus
    /// actor-held arena segments.
    pub fn tenant_used_at(&self, t: Ns) -> u64 {
        self.tenant.used_at(t) + self.tenant_held
    }
}

/// The wired node.
pub struct SimNode {
    pub clock: Clock,
    pub gpus: Vec<Gpu>,
    /// Host DRAM arena (the slow offload tier).
    pub host: Hbm,
    /// CXL memory-expander arena; capacity 0 when the tier is absent.
    pub cxl: Hbm,
    /// NVMe SSD arena (cold tier); capacity 0 when the tier is absent.
    pub ssd: Hbm,
    pub topo: Topology,
    pub dma: DmaEngine,
    /// One pre-created stream per (src,dst) device-pair class, so
    /// subsystems can issue copies without managing stream lifetime.
    h2d_streams: Vec<StreamId>,
    d2h_streams: Vec<StreamId>,
    c2d_streams: Vec<StreamId>,
    d2c_streams: Vec<StreamId>,
    p2p_streams: Vec<Vec<StreamId>>,
    h2s_stream: StreamId,
    s2h_stream: StreamId,
}

impl SimNode {
    pub fn new(spec: NodeSpec) -> Self {
        let clock = Clock::new();
        let n = spec.gpus.len();
        let topo =
            Topology::with_fabric(clock.clone(), n, spec.nvlink, spec.pcie, spec.fabric);
        let mut dma = DmaEngine::new();
        let gpus = spec
            .gpus
            .iter()
            .map(|g| Gpu {
                hbm: Hbm::new(g.hbm_bytes, g.fit),
                tenant: TenantLoad::constant(g.hbm_bytes, 0),
                tenant_held: 0,
            })
            .collect();
        let h2d_streams = (0..n).map(|_| dma.create_stream()).collect();
        let d2h_streams = (0..n).map(|_| dma.create_stream()).collect();
        let c2d_streams = (0..n).map(|_| dma.create_stream()).collect();
        let d2c_streams = (0..n).map(|_| dma.create_stream()).collect();
        let p2p_streams = (0..n).map(|_| (0..n).map(|_| dma.create_stream()).collect()).collect();
        let h2s_stream = dma.create_stream();
        let s2h_stream = dma.create_stream();
        Self {
            clock,
            gpus,
            host: Hbm::new(spec.host_dram_bytes, FitStrategy::BestFit),
            cxl: Hbm::new(spec.cxl_bytes, FitStrategy::BestFit),
            ssd: Hbm::new(spec.ssd_bytes, FitStrategy::BestFit),
            topo,
            dma,
            h2d_streams,
            d2h_streams,
            c2d_streams,
            d2c_streams,
            p2p_streams,
            h2s_stream,
            s2h_stream,
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// Whether the node carries a CXL memory expander.
    pub fn has_cxl(&self) -> bool {
        self.cxl.capacity() > 0
    }

    /// Whether the node carries an NVMe SSD cold tier.
    pub fn has_ssd(&self) -> bool {
        self.ssd.capacity() > 0
    }

    /// Install a tenant-load timeline on GPU `i`.
    pub fn set_tenant_load(&mut self, i: usize, load: TenantLoad) {
        assert_eq!(load.capacity(), self.gpus[i].hbm.capacity(), "timeline capacity mismatch");
        self.gpus[i].tenant = load;
    }

    /// Bytes currently free for harvesting on GPU `i`: capacity minus
    /// co-tenant usage minus what is already allocated in the arena.
    /// Actor-held tenant segments live *inside* the arena (counted by
    /// `hbm.used()`); only the exogenous timeline is added on top.
    pub fn harvestable_now(&self, i: usize) -> u64 {
        let g = &self.gpus[i];
        let tenant_used = g.tenant.used_at(self.clock.now());
        g.hbm.capacity().saturating_sub(tenant_used).saturating_sub(g.hbm.used())
    }

    /// The default stream for a (src → dst) copy.
    pub fn stream_for(&self, src: DeviceId, dst: DeviceId) -> StreamId {
        match (src, dst) {
            (DeviceId::Host, DeviceId::Gpu(d)) => self.h2d_streams[d],
            (DeviceId::Gpu(d), DeviceId::Host) => self.d2h_streams[d],
            (DeviceId::Cxl, DeviceId::Gpu(d)) => self.c2d_streams[d],
            (DeviceId::Gpu(d), DeviceId::Cxl) => self.d2c_streams[d],
            (DeviceId::Gpu(s), DeviceId::Gpu(d)) => self.p2p_streams[s][d],
            (DeviceId::Host, DeviceId::Ssd) => self.h2s_stream,
            (DeviceId::Ssd, DeviceId::Host) => self.s2h_stream,
            (src, dst) => panic!("no direct {src}->{dst} path: stage the copy"),
        }
    }

    /// Async contiguous copy on the default stream; returns the event.
    pub fn copy(
        &mut self,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        tag: Option<u64>,
    ) -> super::dma::CopyEvent {
        let stream = self.stream_for(src, dst);
        self.dma
            .copy(&mut self.topo, stream, src, dst, bytes, tag)
            .expect("copy on wired node cannot fail")
    }

    /// Async two-hop copy staged through GPU `via`: `src → via → dst`,
    /// for endpoint pairs with no direct link (host↔CXL). The second hop
    /// starts when the first delivers (no virtual-time advance); both
    /// hops carry `tag`, so drain-by-tag covers the whole staged move.
    /// Returns a combined event spanning hop 1's start to hop 2's end.
    pub fn copy_via(
        &mut self,
        src: DeviceId,
        via: usize,
        dst: DeviceId,
        bytes: u64,
        tag: Option<u64>,
    ) -> super::dma::CopyEvent {
        assert!(src != dst, "staging between identical endpoints");
        let hop = DeviceId::Gpu(via);
        assert!(src != hop && dst != hop, "staging GPU must differ from both endpoints");
        let first = self.copy(src, hop, bytes, tag);
        let stream2 = self.stream_for(hop, dst);
        let second = self
            .dma
            .copy_after(&mut self.topo, stream2, hop, dst, bytes, tag, first.end)
            .expect("copy on wired node cannot fail");
        super::dma::CopyEvent { start: first.start, end: second.end, bytes, src, dst }
    }

    /// Async multi-hop copy along `path` (≥ 2 endpoints; each adjacent
    /// pair must be a wired link): hop *k+1* starts when hop *k*
    /// delivers, without advancing virtual time, and every hop carries
    /// `tag` so drain-by-tag covers the whole staged move. This is how
    /// link-less endpoint pairs are reached — GPU↔SSD stages through
    /// host DRAM, CXL↔SSD through a GPU *and* host. Returns a combined
    /// event spanning the first hop's start to the last hop's end.
    pub fn copy_path(
        &mut self,
        path: &[DeviceId],
        bytes: u64,
        tag: Option<u64>,
    ) -> super::dma::CopyEvent {
        assert!(path.len() >= 2, "a copy path needs at least two endpoints");
        let first = self.copy(path[0], path[1], bytes, tag);
        let mut last = first;
        for w in path[1..].windows(2) {
            let stream = self.stream_for(w[0], w[1]);
            last = self
                .dma
                .copy_after(&mut self.topo, stream, w[0], w[1], bytes, tag, last.end)
                .expect("copy on wired node cannot fail");
        }
        super::dma::CopyEvent {
            start: first.start,
            end: last.end,
            bytes,
            src: path[0],
            dst: *path.last().unwrap(),
        }
    }

    /// Async scattered copy (n_chunks pieces) on the default stream.
    pub fn copy_scattered(
        &mut self,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        n_chunks: u64,
        tag: Option<u64>,
    ) -> super::dma::CopyEvent {
        let stream = self.stream_for(src, dst);
        self.dma
            .copy_scattered(&mut self.topo, stream, src, dst, bytes, n_chunks, tag)
            .expect("copy on wired node cannot fail")
    }

    /// Synchronize the default (src → dst) stream (advances virtual time).
    pub fn sync(&mut self, src: DeviceId, dst: DeviceId) -> Ns {
        let stream = self.stream_for(src, dst);
        self.dma.sync_stream(&self.topo, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_node_is_two_h100() {
        let node = SimNode::new(NodeSpec::default());
        assert_eq!(node.n_gpus(), 2);
        assert_eq!(node.gpus[0].hbm.capacity(), 80 * GIB);
        assert!(node.topo.link_model(DeviceId::Gpu(0), DeviceId::Gpu(1)).is_some());
        assert!(node.topo.link_model(DeviceId::Gpu(0), DeviceId::Host).is_some());
        // host DRAM is an allocatable arena; CXL absent by default
        assert_eq!(node.host.capacity(), 1024 * GIB);
        assert!(!node.has_cxl());
    }

    #[test]
    fn cxl_spec_attaches_allocatable_arena() {
        let mut node = SimNode::new(NodeSpec::h100x2().with_cxl(256 * GIB));
        assert!(node.has_cxl());
        assert_eq!(node.cxl.capacity(), 256 * GIB);
        let a = node.cxl.alloc(GIB).unwrap();
        let ev = node.copy(DeviceId::Cxl, DeviceId::Gpu(0), GIB, None);
        assert!(ev.end > 0);
        // cxl beats host, loses to nvlink — the intermediate tier
        let host = node.topo.estimate(DeviceId::Host, DeviceId::Gpu(0), GIB).unwrap();
        let nv = node.topo.estimate(DeviceId::Gpu(1), DeviceId::Gpu(0), GIB).unwrap();
        assert!(nv < ev.duration() && ev.duration() < host);
        node.cxl.free(a);
    }

    #[test]
    fn harvestable_accounts_for_tenant_and_own_allocs() {
        let mut node = SimNode::new(NodeSpec::default());
        node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 30 * GIB));
        assert_eq!(node.harvestable_now(1), 50 * GIB);
        let _a = node.gpus[1].hbm.alloc(10 * GIB).unwrap();
        assert_eq!(node.harvestable_now(1), 40 * GIB);
    }

    #[test]
    fn harvestable_saturates_at_zero() {
        let mut node = SimNode::new(NodeSpec::default());
        node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 80 * GIB));
        let _a = node.gpus[1].hbm.alloc(1).unwrap(); // we over-committed
        assert_eq!(node.harvestable_now(1), 0);
    }

    #[test]
    fn copy_and_sync_roundtrip() {
        let mut node = SimNode::new(NodeSpec::default());
        let ev = node.copy(DeviceId::Gpu(0), DeviceId::Gpu(1), 1 << 20, Some(1));
        assert!(ev.end > 0);
        let t = node.sync(DeviceId::Gpu(0), DeviceId::Gpu(1));
        assert_eq!(t, ev.end);
    }

    #[test]
    fn copy_via_stages_through_gpu() {
        let mut node = SimNode::new(NodeSpec::h100x2().with_cxl(64 * GIB));
        // no direct host<->cxl link: the staged path must traverse both
        // GPU-adjacent links, hop 2 strictly after hop 1.
        let ev = node.copy_via(DeviceId::Host, 1, DeviceId::Cxl, 1 << 20, Some(42));
        assert_eq!(node.topo.bytes_moved(DeviceId::Host, DeviceId::Gpu(1)), 1 << 20);
        assert_eq!(node.topo.bytes_moved(DeviceId::Gpu(1), DeviceId::Cxl), 1 << 20);
        let hop1 = node.topo.busy_until(DeviceId::Host, DeviceId::Gpu(1));
        let hop2 = node.topo.busy_until(DeviceId::Gpu(1), DeviceId::Cxl);
        assert!(hop2 > hop1, "second hop waits for the first");
        assert_eq!(ev.end, hop2);
        assert_eq!(ev.start, 0);
        // the whole staged move is covered by the tag barrier
        assert_eq!(node.dma.tag_busy_until(42), ev.end);
    }

    #[test]
    fn ssd_spec_attaches_allocatable_arena() {
        let mut node = SimNode::new(NodeSpec::h100x2().with_ssd(1024 * GIB));
        assert!(node.has_ssd());
        assert_eq!(node.ssd.capacity(), 1024 * GIB);
        let a = node.ssd.alloc(GIB).unwrap();
        // the direct rung: host <-> ssd over the NVMe link
        let ev = node.copy(DeviceId::Ssd, DeviceId::Host, GIB, None);
        let host = node.topo.estimate(DeviceId::Host, DeviceId::Gpu(0), GIB).unwrap();
        assert!(ev.duration() > host, "ssd rung is slower than host paging");
        node.ssd.free(a);
        assert!(!SimNode::new(NodeSpec::h100x2()).has_ssd(), "absent by default");
    }

    #[test]
    fn copy_path_stages_gpu_to_ssd_through_host() {
        let mut node = SimNode::new(NodeSpec::h100x2().with_ssd(64 * GIB));
        let path = [DeviceId::Gpu(0), DeviceId::Host, DeviceId::Ssd];
        let ev = node.copy_path(&path, 1 << 20, Some(7));
        assert_eq!(node.topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Host), 1 << 20);
        assert_eq!(node.topo.bytes_moved(DeviceId::Host, DeviceId::Ssd), 1 << 20);
        let hop1 = node.topo.busy_until(DeviceId::Gpu(0), DeviceId::Host);
        let hop2 = node.topo.busy_until(DeviceId::Host, DeviceId::Ssd);
        assert!(hop2 > hop1, "write-back waits for the d2h hop");
        assert_eq!(ev.end, hop2);
        assert_eq!((ev.src, ev.dst), (DeviceId::Gpu(0), DeviceId::Ssd));
        // the whole staged move is covered by the tag barrier
        assert_eq!(node.dma.tag_busy_until(7), ev.end);
    }

    #[test]
    fn tenant_timeline_changes_harvestable_over_time() {
        let mut node = SimNode::new(NodeSpec::default());
        node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 10 * GIB), (1_000, 70 * GIB)]),
        );
        assert_eq!(node.harvestable_now(1), 70 * GIB);
        node.clock.advance_to(1_000);
        assert_eq!(node.harvestable_now(1), 10 * GIB);
    }

    #[test]
    fn nvlink_domain_spec_scales() {
        let node = SimNode::new(NodeSpec::nvlink_domain(8));
        assert_eq!(node.n_gpus(), 8);
        assert!(node.topo.link_model(DeviceId::Gpu(3), DeviceId::Gpu(7)).is_some());
    }
}
