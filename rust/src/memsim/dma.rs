//! Async DMA engine — the `cudaMemcpy{Peer}Async` / stream / event
//! stand-in.
//!
//! Copies are issued on *streams* (FIFO queues). Each copy also contends
//! on the underlying link (shared with other streams using the same
//! endpoint pair). The engine records, per stream and per user *tag*
//! (e.g. a harvest allocation id), when the last touching operation
//! completes — this is what the Harvest revocation pipeline drains before
//! freeing peer memory (§3.2: "Before freeing memory, the runtime drains
//! in-flight DMA and kernel operations that touch the region").

use super::clock::Ns;
use super::interconnect::{DeviceId, Topology};
use std::collections::BTreeMap;

/// FIFO stream handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StreamId(pub u64);

/// A scheduled copy: when it started/completed in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CopyEvent {
    pub start: Ns,
    pub end: Ns,
    pub bytes: u64,
    pub src: DeviceId,
    pub dst: DeviceId,
}

impl CopyEvent {
    pub fn duration(&self) -> Ns {
        self.end - self.start
    }
}

/// The engine. Owns stream state; borrows the topology per call so other
/// components (e.g. compute pipelines) can also schedule on links.
#[derive(Debug, Default)]
pub struct DmaEngine {
    streams: BTreeMap<StreamId, Ns>, // stream -> busy_until
    tags: BTreeMap<u64, Ns>,         // tag -> last op end
    next_stream: u64,
}

impl DmaEngine {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn create_stream(&mut self) -> StreamId {
        let id = StreamId(self.next_stream);
        self.next_stream += 1;
        self.streams.insert(id, 0);
        id
    }

    /// Issue an async contiguous copy on `stream` at earliest the current
    /// clock time; `tag` associates the op with a region for drains.
    pub fn copy(
        &mut self,
        topo: &mut Topology,
        stream: StreamId,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        tag: Option<u64>,
    ) -> Option<CopyEvent> {
        self.copy_after(topo, stream, src, dst, bytes, tag, 0)
    }

    /// Like [`DmaEngine::copy`], but the op starts no earlier than
    /// `earliest` (in addition to the clock and the stream's FIFO
    /// order). This is how a dependent second hop of a staged transfer
    /// (e.g. host→GPU→CXL, which has no direct link) waits for its first
    /// hop without advancing virtual time.
    pub fn copy_after(
        &mut self,
        topo: &mut Topology,
        stream: StreamId,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        tag: Option<u64>,
        earliest: Ns,
    ) -> Option<CopyEvent> {
        let now = topo.clock().now();
        let sbusy = self.streams.get_mut(&stream)?;
        let at = now.max(*sbusy).max(earliest);
        let (start, end) = topo.schedule(src, dst, bytes, at)?;
        *sbusy = end;
        if let Some(t) = tag {
            let e = self.tags.entry(t).or_insert(0);
            *e = (*e).max(end);
        }
        Some(CopyEvent { start, end, bytes, src, dst })
    }

    /// Issue a *scattered* copy: `n_chunks` back-to-back chunk copies on
    /// one stream (e.g. per-block KV reloads, which are many small
    /// non-contiguous regions — each chunk pays the link's per-transfer
    /// base latency). Returns the overall (first start, last end).
    pub fn copy_scattered(
        &mut self,
        topo: &mut Topology,
        stream: StreamId,
        src: DeviceId,
        dst: DeviceId,
        total_bytes: u64,
        n_chunks: u64,
        tag: Option<u64>,
    ) -> Option<CopyEvent> {
        assert!(n_chunks > 0);
        let chunk = total_bytes / n_chunks;
        let rem = total_bytes % n_chunks;
        let mut first_start = None;
        let mut last_end = 0;
        for i in 0..n_chunks {
            let b = chunk + if i < rem { 1 } else { 0 };
            let ev = self.copy(topo, stream, src, dst, b, tag)?;
            first_start.get_or_insert(ev.start);
            last_end = ev.end;
        }
        Some(CopyEvent { start: first_start.unwrap(), end: last_end, bytes: total_bytes, src, dst })
    }

    /// When all ops issued so far on `stream` complete.
    pub fn stream_busy_until(&self, stream: StreamId) -> Ns {
        self.streams.get(&stream).copied().unwrap_or(0)
    }

    /// Block (advance virtual time) until `stream` is idle; returns the
    /// new now. The `cudaStreamSynchronize` stand-in.
    pub fn sync_stream(&mut self, topo: &Topology, stream: StreamId) -> Ns {
        let t = self.stream_busy_until(stream);
        topo.clock().advance_to(t)
    }

    /// When the last operation touching `tag` completes (0 if none).
    pub fn tag_busy_until(&self, tag: u64) -> Ns {
        self.tags.get(&tag).copied().unwrap_or(0)
    }

    /// Drain all in-flight ops touching `tag`: advance virtual time past
    /// them and forget the tag. The revocation pre-free barrier —
    /// background (prefetch) transfers are covered by it exactly like
    /// demand DMA; consumers keep the barrier off the hot path by only
    /// freeing once the tagged copy has already completed (see
    /// [`crate::harvest::session::Transfer::background`]).
    pub fn drain_tag(&mut self, topo: &Topology, tag: u64) -> Ns {
        let t = self.tags.remove(&tag).unwrap_or(0);
        topo.clock().advance_to(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::clock::Clock;

    const MIB: u64 = 1024 * 1024;

    fn setup() -> (Topology, DmaEngine) {
        let clock = Clock::new();
        (Topology::h100_node(clock, 2), DmaEngine::new())
    }

    #[test]
    fn copies_on_one_stream_serialize() {
        let (mut topo, mut dma) = setup();
        let s = dma.create_stream();
        let a = dma.copy(&mut topo, s, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, None).unwrap();
        let b = dma.copy(&mut topo, s, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, None).unwrap();
        assert_eq!(b.start, a.end);
    }

    #[test]
    fn different_streams_still_contend_on_same_link() {
        let (mut topo, mut dma) = setup();
        let s1 = dma.create_stream();
        let s2 = dma.create_stream();
        let a = dma.copy(&mut topo, s1, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, None).unwrap();
        let b = dma.copy(&mut topo, s2, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, None).unwrap();
        // link FIFO: second transfer starts when first ends
        assert_eq!(b.start, a.end);
    }

    #[test]
    fn different_links_overlap_across_streams() {
        let (mut topo, mut dma) = setup();
        let s1 = dma.create_stream();
        let s2 = dma.create_stream();
        let a = dma.copy(&mut topo, s1, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, None).unwrap();
        let b = dma.copy(&mut topo, s2, DeviceId::Host, DeviceId::Gpu(0), MIB, None).unwrap();
        assert_eq!(a.start, 0);
        assert_eq!(b.start, 0, "independent links overlap");
    }

    #[test]
    fn sync_stream_advances_clock() {
        let (mut topo, mut dma) = setup();
        let s = dma.create_stream();
        let ev = dma.copy(&mut topo, s, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, None).unwrap();
        assert_eq!(topo.clock().now(), 0, "copy is async");
        let t = dma.sync_stream(&topo, s);
        assert_eq!(t, ev.end);
        assert_eq!(topo.clock().now(), ev.end);
    }

    #[test]
    fn drain_tag_waits_for_all_touching_ops() {
        let (mut topo, mut dma) = setup();
        let s1 = dma.create_stream();
        let s2 = dma.create_stream();
        let _a =
            dma.copy(&mut topo, s1, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, Some(7)).unwrap();
        let b =
            dma.copy(&mut topo, s2, DeviceId::Gpu(1), DeviceId::Gpu(0), 4 * MIB, Some(7)).unwrap();
        assert_eq!(dma.tag_busy_until(7), b.end);
        let t = dma.drain_tag(&topo, 7);
        assert_eq!(t, b.end);
        // tag forgotten after drain
        assert_eq!(dma.tag_busy_until(7), 0);
    }

    #[test]
    fn drain_of_completed_tag_is_a_noop_barrier() {
        let (mut topo, mut dma) = setup();
        let s = dma.create_stream();
        let ev =
            dma.copy(&mut topo, s, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, Some(9)).unwrap();
        // once virtual time has passed the op, draining costs nothing —
        // the property the deferred-release prefetch path relies on
        topo.clock().advance_to(ev.end + 10);
        let before = topo.clock().now();
        assert_eq!(dma.drain_tag(&topo, 9), before, "no further advance");
        assert_eq!(dma.tag_busy_until(9), 0, "tag forgotten after drain");
    }

    #[test]
    fn scattered_copy_pays_per_chunk_overhead() {
        let (mut topo, mut dma) = setup();
        let s = dma.create_stream();
        let total = 8 * MIB;
        let one = dma
            .copy(&mut topo, s, DeviceId::Gpu(0), DeviceId::Gpu(1), total, None)
            .unwrap()
            .duration();
        let (mut topo2, mut dma2) = setup();
        let s2 = dma2.create_stream();
        let many = dma2
            .copy_scattered(&mut topo2, s2, DeviceId::Gpu(0), DeviceId::Gpu(1), total, 16, None)
            .unwrap();
        assert!(
            many.end - many.start > one,
            "16 scattered chunks ({}) must be slower than 1 contiguous ({one})",
            many.end - many.start
        );
    }

    #[test]
    fn scattered_copy_moves_exact_total() {
        let (mut topo, mut dma) = setup();
        let s = dma.create_stream();
        // 100 bytes in 7 chunks: remainders distributed, total preserved.
        dma.copy_scattered(&mut topo, s, DeviceId::Gpu(0), DeviceId::Host, 100, 7, None).unwrap();
        assert_eq!(topo.bytes_moved(DeviceId::Gpu(0), DeviceId::Host), 100);
        assert_eq!(topo.transfers(DeviceId::Gpu(0), DeviceId::Host), 7);
    }

    #[test]
    fn copy_after_respects_dependency() {
        let (mut topo, mut dma) = setup();
        let s1 = dma.create_stream();
        let s2 = dma.create_stream();
        // hop 1: host -> gpu0; hop 2 (gpu0 -> gpu1) must not start before
        // hop 1 delivered the bytes, even though the links are disjoint.
        let hop1 =
            dma.copy(&mut topo, s1, DeviceId::Host, DeviceId::Gpu(0), MIB, Some(3)).unwrap();
        let hop2 = dma
            .copy_after(&mut topo, s2, DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, Some(3), hop1.end)
            .unwrap();
        assert_eq!(hop2.start, hop1.end);
        assert_eq!(dma.tag_busy_until(3), hop2.end, "both hops share the tag");
        // earliest in the past degenerates to a plain copy
        let plain = dma
            .copy_after(&mut topo, s1, DeviceId::Host, DeviceId::Gpu(0), MIB, None, 0)
            .unwrap();
        assert_eq!(plain.start, hop1.end, "link FIFO still applies");
    }

    #[test]
    fn copy_to_unknown_stream_is_none() {
        let (mut topo, mut dma) = setup();
        let bogus = StreamId(99);
        assert!(dma.copy(&mut topo, bogus, DeviceId::Gpu(0), DeviceId::Gpu(1), 1, None).is_none());
    }
}
