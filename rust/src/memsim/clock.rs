//! Virtual nanosecond clock shared by all simulation components.
//!
//! The simulation is single-threaded and deterministic: components advance
//! the clock explicitly (`advance`, `advance_to`) and resources model
//! contention by tracking their own `busy_until` horizon against it.

use std::cell::Cell;
use std::rc::Rc;

/// Virtual time in nanoseconds.
pub type Ns = u64;

/// Shared, cheap-to-clone handle to the simulation's current time.
#[derive(Debug, Clone, Default)]
pub struct Clock(Rc<Cell<Ns>>);

impl Clock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> Ns {
        self.0.get()
    }

    /// Move time forward by `d` ns; returns the new now.
    pub fn advance(&self, d: Ns) -> Ns {
        let t = self.0.get() + d;
        self.0.set(t);
        t
    }

    /// Move time forward to `t` (no-op if `t` is in the past — virtual
    /// time never goes backwards).
    pub fn advance_to(&self, t: Ns) -> Ns {
        if t > self.0.get() {
            self.0.set(t);
        }
        self.0.get()
    }
}

/// Convert seconds to [`Ns`].
pub fn secs(s: f64) -> Ns {
    (s * 1e9) as Ns
}

/// Convert microseconds to [`Ns`].
pub fn micros(us: f64) -> Ns {
    (us * 1e3) as Ns
}

/// Convert [`Ns`] to seconds.
pub fn to_secs(ns: Ns) -> f64 {
    ns as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero_and_advances() {
        let c = Clock::new();
        assert_eq!(c.now(), 0);
        assert_eq!(c.advance(10), 10);
        assert_eq!(c.now(), 10);
    }

    #[test]
    fn clones_share_time() {
        let a = Clock::new();
        let b = a.clone();
        a.advance(5);
        assert_eq!(b.now(), 5);
        b.advance_to(100);
        assert_eq!(a.now(), 100);
    }

    #[test]
    fn advance_to_never_rewinds() {
        let c = Clock::new();
        c.advance_to(50);
        assert_eq!(c.advance_to(20), 50);
        assert_eq!(c.now(), 50);
    }

    #[test]
    fn unit_helpers() {
        assert_eq!(secs(1.5), 1_500_000_000);
        assert_eq!(micros(2.0), 2_000);
        assert!((to_secs(500_000_000) - 0.5).abs() < 1e-12);
    }
}
