//! HBM segment allocator — the `cudaMalloc`/`cudaFree` stand-in.
//!
//! A sorted free-list allocator over a fixed byte range with pluggable
//! fit strategies. The Harvest controller's default is best-fit, matching
//! the paper (§3.2: "a best-fit strategy that chooses a peer GPU and a
//! free segment that minimize leftover fragmentation").
//!
//! Invariants (enforced in debug asserts + property tests):
//! * allocated segments never overlap;
//! * free segments are sorted, non-adjacent (always coalesced), non-empty;
//! * used + free == capacity.

use std::collections::{BTreeMap, BTreeSet};

/// Opaque allocation handle (monotonically increasing, never reused).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct AllocId(pub u64);

/// Why an allocation failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocError {
    /// Not enough total free bytes.
    OutOfMemory { requested: u64, free: u64 },
    /// Enough free bytes but no contiguous segment fits (fragmentation).
    Fragmented { requested: u64, largest_free: u64 },
    /// Zero-sized request.
    ZeroSize,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::OutOfMemory { requested, free } => {
                write!(f, "out of memory: requested {requested}, free {free}")
            }
            AllocError::Fragmented { requested, largest_free } => {
                write!(f, "fragmented: requested {requested}, largest free {largest_free}")
            }
            AllocError::ZeroSize => write!(f, "zero-size allocation"),
        }
    }
}

impl std::error::Error for AllocError {}

/// Free-segment selection strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FitStrategy {
    /// Smallest segment that fits (minimises leftover fragmentation —
    /// the paper's default).
    #[default]
    BestFit,
    /// Lowest-offset segment that fits.
    FirstFit,
    /// Largest segment (keeps small holes for small requests).
    WorstFit,
}

/// One device's HBM arena.
#[derive(Debug, Clone)]
pub struct Hbm {
    capacity: u64,
    strategy: FitStrategy,
    /// offset -> length, sorted, coalesced.
    free: BTreeMap<u64, u64>,
    /// (length, offset) index over `free` for O(log n) best/worst-fit
    /// (EXPERIMENTS.md §Perf).
    free_by_size: BTreeSet<(u64, u64)>,
    /// id -> (offset, length).
    allocs: BTreeMap<AllocId, (u64, u64)>,
    /// Incremental sum of live allocation lengths (O(1) `used()`).
    used: u64,
    next_id: u64,
    /// Cumulative counters for metrics.
    pub total_allocs: u64,
    pub total_frees: u64,
    pub failed_allocs: u64,
}

impl Hbm {
    pub fn new(capacity: u64, strategy: FitStrategy) -> Self {
        let mut free = BTreeMap::new();
        let mut free_by_size = BTreeSet::new();
        if capacity > 0 {
            free.insert(0, capacity);
            free_by_size.insert((capacity, 0));
        }
        Self {
            capacity,
            strategy,
            free,
            free_by_size,
            allocs: BTreeMap::new(),
            used: 0,
            next_id: 0,
            total_allocs: 0,
            total_frees: 0,
            failed_allocs: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free_bytes(&self) -> u64 {
        self.free.values().sum()
    }

    pub fn largest_free(&self) -> u64 {
        self.free_by_size.last().map(|&(len, _)| len).unwrap_or(0)
    }

    pub fn num_allocs(&self) -> usize {
        self.allocs.len()
    }

    /// External fragmentation in [0,1]: 1 - largest_free/free (0 when
    /// empty or when all free space is one segment).
    pub fn fragmentation(&self) -> f64 {
        let free = self.free_bytes();
        if free == 0 {
            0.0
        } else {
            1.0 - self.largest_free() as f64 / free as f64
        }
    }

    /// Allocate `size` bytes; returns a handle or why it failed.
    pub fn alloc(&mut self, size: u64) -> Result<AllocId, AllocError> {
        if size == 0 {
            return Err(AllocError::ZeroSize);
        }
        let pick = self.pick_segment(size);
        let Some(offset) = pick else {
            self.failed_allocs += 1;
            let free = self.free_bytes();
            return Err(if size > free {
                AllocError::OutOfMemory { requested: size, free }
            } else {
                AllocError::Fragmented { requested: size, largest_free: self.largest_free() }
            });
        };
        let seg_len = self.free.remove(&offset).expect("picked segment exists");
        self.free_by_size.remove(&(seg_len, offset));
        debug_assert!(seg_len >= size);
        if seg_len > size {
            self.free.insert(offset + size, seg_len - size);
            self.free_by_size.insert((seg_len - size, offset + size));
        }
        let id = AllocId(self.next_id);
        self.next_id += 1;
        self.allocs.insert(id, (offset, size));
        self.used += size;
        self.total_allocs += 1;
        debug_assert_eq!(self.used() + self.free_bytes(), self.capacity);
        Ok(id)
    }

    fn pick_segment(&self, size: u64) -> Option<u64> {
        match self.strategy {
            FitStrategy::FirstFit => self
                .free
                .iter()
                .find(|&(_, &len)| len >= size)
                .map(|(&off, _)| off),
            // Smallest fitting length, lowest offset among equals:
            // exactly the (len, off) order of the size index.
            FitStrategy::BestFit => self
                .free_by_size
                .range((size, 0)..)
                .next()
                .map(|&(_, off)| off),
            // Largest length; lowest offset among equals. The index ends
            // with the largest lengths, highest offset last — scan the
            // equal-length run from its first element.
            FitStrategy::WorstFit => {
                let &(len, _) = self.free_by_size.last()?;
                if len < size {
                    return None;
                }
                self.free_by_size.range((len, 0)..).next().map(|&(_, off)| off)
            }
        }
    }

    /// Free a previous allocation. Returns its size. Panics on
    /// double-free (a correctness bug in the caller, not a runtime
    /// condition).
    pub fn free(&mut self, id: AllocId) -> u64 {
        let (offset, len) = self.allocs.remove(&id).expect("double free or bogus AllocId");
        self.used -= len;
        self.insert_free(offset, len);
        self.total_frees += 1;
        debug_assert_eq!(self.used() + self.free_bytes(), self.capacity);
        len
    }

    fn insert_free(&mut self, mut offset: u64, mut len: u64) {
        // Coalesce with predecessor.
        if let Some((&poff, &plen)) = self.free.range(..offset).next_back() {
            debug_assert!(poff + plen <= offset, "free list overlap");
            if poff + plen == offset {
                self.free.remove(&poff);
                self.free_by_size.remove(&(plen, poff));
                offset = poff;
                len += plen;
            }
        }
        // Coalesce with successor.
        if let Some((&soff, &slen)) = self.free.range(offset + len..).next() {
            if offset + len == soff {
                self.free.remove(&soff);
                self.free_by_size.remove(&(slen, soff));
                len += slen;
            }
        }
        self.free.insert(offset, len);
        self.free_by_size.insert((len, offset));
    }

    /// Shrink a live allocation in place to `new_size` bytes, returning
    /// the tail to the free list (the segment keeps its offset). Returns
    /// the number of bytes released. This is how modeled in-place KV
    /// compression reclaims capacity without requiring free headroom —
    /// an alloc-new-then-free dance could not run on a full arena.
    ///
    /// Panics on a dead id or a grow request (`new_size` must be in
    /// `1..=current size`); a no-op shrink to the current size returns 0.
    pub fn shrink(&mut self, id: AllocId, new_size: u64) -> u64 {
        assert!(new_size > 0, "shrink to zero is a free");
        let (offset, len) = *self.allocs.get(&id).expect("shrink of dead AllocId");
        assert!(new_size <= len, "shrink cannot grow: {new_size} > {len}");
        let released = len - new_size;
        if released == 0 {
            return 0;
        }
        self.allocs.insert(id, (offset, new_size));
        self.used -= released;
        self.insert_free(offset + new_size, released);
        debug_assert_eq!(self.used() + self.free_bytes(), self.capacity);
        released
    }

    /// Size of an allocation, if live.
    pub fn size_of(&self, id: AllocId) -> Option<u64> {
        self.allocs.get(&id).map(|&(_, len)| len)
    }

    /// Offset of an allocation (the simulated device pointer), if live.
    pub fn offset_of(&self, id: AllocId) -> Option<u64> {
        self.allocs.get(&id).map(|&(off, _)| off)
    }

    pub fn contains(&self, id: AllocId) -> bool {
        self.allocs.contains_key(&id)
    }

    /// Live allocation ids, ascending (== allocation order).
    pub fn alloc_ids(&self) -> Vec<AllocId> {
        self.allocs.keys().copied().collect()
    }

    /// Verify all internal invariants; returns a description of the first
    /// violation. Used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.used != self.allocs.values().map(|&(_, len)| len).sum::<u64>() {
            return Err("used counter out of sync".into());
        }
        if self.free.len() != self.free_by_size.len()
            || !self
                .free
                .iter()
                .all(|(&o, &l)| self.free_by_size.contains(&(l, o)))
        {
            return Err("free list and size index out of sync".into());
        }
        let mut regions: Vec<(u64, u64, bool)> = self
            .free
            .iter()
            .map(|(&o, &l)| (o, l, true))
            .chain(self.allocs.values().map(|&(o, l)| (o, l, false)))
            .collect();
        regions.sort_unstable();
        let mut cursor = 0u64;
        let mut prev_free = false;
        for (off, len, is_free) in regions {
            if len == 0 {
                return Err(format!("zero-length region at {off}"));
            }
            if off != cursor {
                return Err(format!("gap or overlap at {off}, expected {cursor}"));
            }
            if is_free && prev_free {
                return Err(format!("uncoalesced free segments at {off}"));
            }
            prev_free = is_free;
            cursor = off + len;
        }
        if cursor != self.capacity {
            return Err(format!("regions end at {cursor}, capacity {}", self.capacity));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut h = Hbm::new(1000, FitStrategy::BestFit);
        let a = h.alloc(100).unwrap();
        let b = h.alloc(200).unwrap();
        assert_eq!(h.used(), 300);
        assert_eq!(h.free(a), 100);
        assert_eq!(h.free(b), 200);
        assert_eq!(h.used(), 0);
        assert_eq!(h.free_bytes(), 1000);
        assert_eq!(h.largest_free(), 1000); // fully coalesced
        h.check_invariants().unwrap();
    }

    #[test]
    fn oom_reports_reason() {
        let mut h = Hbm::new(100, FitStrategy::BestFit);
        let _a = h.alloc(80).unwrap();
        match h.alloc(50) {
            Err(AllocError::OutOfMemory { requested: 50, free: 20 }) => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(h.failed_allocs, 1);
    }

    #[test]
    fn fragmentation_reported_when_total_fits_but_no_segment_does() {
        let mut h = Hbm::new(300, FitStrategy::FirstFit);
        let a = h.alloc(100).unwrap();
        let _b = h.alloc(100).unwrap();
        let _c = h.alloc(100).unwrap();
        h.free(a); // free 100 at offset 0
        // Now free = 100 contiguous; ask for 150 -> OOM (only 100 free).
        match h.alloc(150) {
            Err(AllocError::OutOfMemory { .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fragmented_error_variant() {
        let mut h = Hbm::new(400, FitStrategy::FirstFit);
        let a = h.alloc(100).unwrap();
        let _b = h.alloc(100).unwrap();
        let c = h.alloc(100).unwrap();
        let _d = h.alloc(100).unwrap();
        h.free(a);
        h.free(c);
        // 200 free total, but in two 100-byte holes.
        match h.alloc(150) {
            Err(AllocError::Fragmented { requested: 150, largest_free: 100 }) => {}
            other => panic!("{other:?}"),
        }
        h.check_invariants().unwrap();
    }

    #[test]
    fn best_fit_picks_smallest_hole() {
        let mut h = Hbm::new(1000, FitStrategy::BestFit);
        let a = h.alloc(300).unwrap(); // [0,300)
        let b = h.alloc(100).unwrap(); // [300,400)
        let _c = h.alloc(600).unwrap(); // [400,1000)
        h.free(a);
        h.free(b);
        // coalesced -> single hole [0,400). Re-carve: alloc 300 then 100.
        let d = h.alloc(300).unwrap();
        assert_eq!(h.offset_of(d), Some(0));
        // Now holes: [300,400). Alloc 50 must land there (best fit).
        let e = h.alloc(50).unwrap();
        assert_eq!(h.offset_of(e), Some(300));
        h.check_invariants().unwrap();
    }

    #[test]
    fn first_fit_vs_best_fit_choice() {
        // Two holes: big at low offset, small at high offset.
        let mk = |strategy| {
            let mut h = Hbm::new(1000, strategy);
            let a = h.alloc(500).unwrap(); // [0,500)
            let _keep = h.alloc(100).unwrap(); // [500,600)
            let b = h.alloc(100).unwrap(); // [600,700)
            let _keep2 = h.alloc(300).unwrap(); // [700,1000)
            h.free(a); // hole [0,500)
            h.free(b); // hole [600,700)
            h
        };
        let mut first = mk(FitStrategy::FirstFit);
        let f = first.alloc(100).unwrap();
        assert_eq!(first.offset_of(f), Some(0));
        let mut best = mk(FitStrategy::BestFit);
        let g = best.alloc(100).unwrap();
        assert_eq!(best.offset_of(g), Some(600));
        let mut worst = mk(FitStrategy::WorstFit);
        let w = worst.alloc(100).unwrap();
        assert_eq!(worst.offset_of(w), Some(0));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut h = Hbm::new(100, FitStrategy::BestFit);
        let a = h.alloc(10).unwrap();
        h.free(a);
        h.free(a);
    }

    #[test]
    fn shrink_releases_tail_in_place() {
        let mut h = Hbm::new(1000, FitStrategy::BestFit);
        let a = h.alloc(400).unwrap(); // [0,400)
        let _b = h.alloc(600).unwrap(); // [400,1000) — arena is FULL
        assert_eq!(h.free_bytes(), 0);
        // shrink works with zero headroom: the compression use case
        assert_eq!(h.shrink(a, 100), 300);
        assert_eq!(h.size_of(a), Some(100));
        assert_eq!(h.offset_of(a), Some(0), "segment keeps its offset");
        assert_eq!(h.free_bytes(), 300);
        assert_eq!(h.used(), 700);
        // released tail is allocatable and coalesces on free
        let c = h.alloc(300).unwrap();
        assert_eq!(h.offset_of(c), Some(100));
        h.free(c);
        h.free(a);
        assert_eq!(h.largest_free(), 400);
        // no-op shrink
        let d = h.alloc(50).unwrap();
        assert_eq!(h.shrink(d, 50), 0);
        h.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "cannot grow")]
    fn shrink_grow_panics() {
        let mut h = Hbm::new(100, FitStrategy::BestFit);
        let a = h.alloc(10).unwrap();
        h.shrink(a, 20);
    }

    #[test]
    fn zero_size_rejected() {
        let mut h = Hbm::new(100, FitStrategy::BestFit);
        assert_eq!(h.alloc(0), Err(AllocError::ZeroSize));
    }

    #[test]
    fn ids_never_reused() {
        let mut h = Hbm::new(100, FitStrategy::BestFit);
        let a = h.alloc(10).unwrap();
        h.free(a);
        let b = h.alloc(10).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn fragmentation_metric() {
        let mut h = Hbm::new(400, FitStrategy::FirstFit);
        assert_eq!(h.fragmentation(), 0.0);
        let a = h.alloc(100).unwrap();
        let _b = h.alloc(100).unwrap();
        let c = h.alloc(100).unwrap();
        h.free(a);
        h.free(c);
        // holes: 100 + (100+100 tail coalesced = 200) -> largest 200 of 300
        assert!((h.fragmentation() - (1.0 - 200.0 / 300.0)).abs() < 1e-12);
    }
}
