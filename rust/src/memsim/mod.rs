//! Calibrated multi-GPU node simulation — the hardware substrate.
//!
//! The paper's testbed (2× H100 + 12 NVLink links + PCIe 5.0 + MIG + CUDA
//! P2P) does not exist on this image, so per DESIGN.md's substitution rule
//! everything Harvest touches is reproduced as a deterministic
//! *virtual-time* simulation with the same API shape as the CUDA path:
//!
//! * [`clock`] — the virtual nanosecond clock all components share.
//! * [`hbm`] — per-GPU HBM segment allocator (`cudaMalloc` stand-in)
//!   with pluggable fit strategies.
//! * [`interconnect`] — NVLink / PCIe link model: base latency +
//!   size-dependent effective bandwidth + FIFO contention, calibrated so
//!   the GPU↔GPU : CPU↔GPU latency ratio reproduces Fig. 3 (7.5–9.5×).
//! * [`dma`] — async copy engine (`cudaMemcpyPeerAsync` stand-in):
//!   streams, completion events, and the drain-before-free ordering the
//!   Harvest revocation pipeline relies on.
//! * [`node`] — a whole server: GPUs + host DRAM + topology.
//! * [`tenant`] — background co-tenant memory pressure, sampled from the
//!   Alibaba-gpu-v2020-like utilisation distribution of Fig. 2.

pub mod clock;
pub mod collective;
pub mod dma;
pub mod hbm;
pub mod interconnect;
pub mod node;
pub mod tenant;

pub use clock::{Clock, Ns};
pub use collective::{CollectivePattern, CollectiveTraffic};
pub use dma::{CopyEvent, DmaEngine, StreamId};
pub use hbm::{AllocError, AllocId, FitStrategy, Hbm};
pub use interconnect::{
    DeviceId, FabricKind, LinkKind, LinkModel, NodeFabric, NodeFabricKind, Topology,
};
pub use node::{GpuSpec, NodeSpec, SimNode};
pub use tenant::{TenantLoad, UtilizationModel};
