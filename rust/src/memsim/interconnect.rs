//! Interconnect model: NVLink and PCIe links with base latency,
//! size-dependent effective bandwidth, and FIFO contention.
//!
//! ## Calibration (DESIGN.md §Calibration)
//!
//! The paper's testbed is an Azure NC80adis H100 v5: PCIe 5.0 x16 to host
//! and a 12-link NVLink-4 bridge between the two H100s. We model each
//! link direction as
//!
//! ```text
//! latency(bytes) = base_latency + bytes / eff_bw(bytes)
//! eff_bw(bytes)  = peak_bw * bytes / (bytes + half_sat)
//! ```
//!
//! i.e. small transfers are latency-dominated and large transfers
//! approach peak bandwidth with a half-saturation constant. Constants are
//! chosen so the GPU↔GPU : CPU↔GPU latency ratio over Fig. 3's chunk
//! sizes (17 MB Phi-tiny expert → 352 MB Mixtral expert) lands in the
//! paper's observed 7.5×–9.5× band, and Fig. 7's scattered per-block KV
//! reloads land in its 3×–5.7× band (scattered copies pay per-chunk
//! overheads that hurt NVLink's advantage — see `DmaEngine::
//! copy_scattered`).

use super::clock::{Clock, Ns};
use std::collections::BTreeMap;

/// A device endpoint in the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DeviceId {
    /// GPU index within the node.
    Gpu(usize),
    /// Host DRAM (CPU side).
    Host,
    /// CXL-attached memory expander (§8) — an intermediate tier between
    /// peer HBM and host DRAM, reached over [`LinkModel::cxl_mem`]-class
    /// links from every GPU.
    Cxl,
    /// NVMe SSD arena behind the host bridge (the cold-tier ladder's
    /// last rung): reached only over a [`LinkModel::nvme_ssd`]-class link
    /// from the host — GPUs stage SSD traffic through host DRAM.
    Ssd,
}

impl std::fmt::Display for DeviceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeviceId::Gpu(i) => write!(f, "gpu{i}"),
            DeviceId::Host => write!(f, "host"),
            DeviceId::Cxl => write!(f, "cxl"),
            DeviceId::Ssd => write!(f, "ssd"),
        }
    }
}

/// Kind of physical link between two endpoints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// GPU↔GPU peer link (NVLink-4-class).
    NvLink,
    /// GPU↔host link (PCIe 5.0 x16-class).
    Pcie,
    /// Node↔node network link (RDMA / Ethernet NIC-class).
    Nic,
}

/// Analytic latency/bandwidth model of one link direction.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub kind: LinkKind,
    /// Fixed per-transfer overhead (driver + DMA setup + page handling).
    pub base_latency_ns: Ns,
    /// Asymptotic bandwidth in bytes/ns (== GB/s / 1e0... 1 GB/s = 1e9
    /// bytes/s = 1 byte/ns precisely with GB = 1e9).
    pub peak_bw_bytes_per_ns: f64,
    /// Transfer size at which effective bandwidth reaches peak/2.
    pub half_sat_bytes: f64,
}

impl LinkModel {
    /// NVLink-4-class bridge (12 links aggregated): ~450 GB/s effective
    /// peak for large contiguous copies, ~8 µs setup.
    pub fn nvlink_h100() -> Self {
        Self {
            kind: LinkKind::NvLink,
            base_latency_ns: 8_000,
            peak_bw_bytes_per_ns: 450.0,
            half_sat_bytes: 4.0 * 1024.0 * 1024.0,
        }
    }

    /// PCIe 5.0 x16-class host link: ~47 GB/s effective peak (pinned
    /// memory, protocol overheads), ~30 µs setup including host paging.
    pub fn pcie5_host() -> Self {
        Self {
            kind: LinkKind::Pcie,
            base_latency_ns: 30_000,
            peak_bw_bytes_per_ns: 47.0,
            half_sat_bytes: 1.0 * 1024.0 * 1024.0,
        }
    }

    /// CXL-attached memory expander (§8 "potentially CXL-attached
    /// memory"): CXL 3.x x8-class — lower setup latency than the
    /// host-paging PCIe path but similar asymptotic bandwidth, i.e. an
    /// intermediate tier between peer HBM and host DRAM.
    pub fn cxl_mem() -> Self {
        Self {
            kind: LinkKind::Pcie,
            base_latency_ns: 6_000,
            peak_bw_bytes_per_ns: 56.0,
            half_sat_bytes: 1.0 * 1024.0 * 1024.0,
        }
    }

    /// Datacenter NVMe SSD behind the host bridge (PCIe 4.0 x4-class
    /// drive): ~GB/s-class sequential bandwidth — an order of magnitude
    /// below the host-paging PCIe path — plus ~90 µs of submission-queue
    /// + FTL setup. The cold-tier ladder's capacity rung: effectively
    /// unbounded bytes at block-device speed.
    pub fn nvme_ssd() -> Self {
        Self {
            kind: LinkKind::Pcie,
            base_latency_ns: 90_000,
            peak_bw_bytes_per_ns: 6.5,
            half_sat_bytes: 4.0 * 1024.0 * 1024.0,
        }
    }

    /// 400 Gb/s RDMA NIC (ConnectX/EFA-class): GPUDirect-style inter-node
    /// path — ~45 GB/s effective after protocol overheads, ~15 µs setup
    /// (QP posting + rendezvous). The fast inter-node fabric class used
    /// by [`NodeFabric`].
    pub fn rdma_nic() -> Self {
        Self {
            kind: LinkKind::Nic,
            base_latency_ns: 15_000,
            peak_bw_bytes_per_ns: 45.0,
            half_sat_bytes: 2.0 * 1024.0 * 1024.0,
        }
    }

    /// 100 Gb/s Ethernet NIC with a TCP-class stack: ~11 GB/s effective,
    /// ~60 µs setup (kernel stack + copies). The cost-reduced inter-node
    /// fabric class used by [`NodeFabric`].
    pub fn ethernet_100g() -> Self {
        Self {
            kind: LinkKind::Nic,
            base_latency_ns: 60_000,
            peak_bw_bytes_per_ns: 11.0,
            half_sat_bytes: 1.0 * 1024.0 * 1024.0,
        }
    }

    /// Derived model for an `hops`-hop path on a multi-hop fabric: each
    /// hop adds setup latency; cut-through keeps asymptotic bandwidth.
    pub fn with_hops(self, hops: u64) -> Self {
        Self { base_latency_ns: self.base_latency_ns * hops.max(1), ..self }
    }

    /// Effective bandwidth for a transfer of `bytes` (bytes/ns).
    pub fn eff_bw(&self, bytes: u64) -> f64 {
        let b = bytes as f64;
        self.peak_bw_bytes_per_ns * b / (b + self.half_sat_bytes)
    }

    /// Unloaded one-way latency of a `bytes`-sized contiguous transfer.
    pub fn latency(&self, bytes: u64) -> Ns {
        if bytes == 0 {
            return self.base_latency_ns;
        }
        self.base_latency_ns + (bytes as f64 / self.eff_bw(bytes)) as Ns
    }
}

/// One directed link instance with FIFO contention: transfers serialize,
/// each starting no earlier than the previous one finished.
#[derive(Debug, Clone)]
struct Link {
    model: LinkModel,
    busy_until: Ns,
    /// Cumulative bytes moved + transfer count (metrics).
    bytes_moved: u64,
    transfers: u64,
}

/// The node's link fabric: a map from (src, dst) to a link.
///
/// GPU↔GPU pairs get NVLink; every GPU↔Host pair gets PCIe. Transfers
/// between the same endpoints share the link and contend FIFO; distinct
/// pairs are independent (own DMA engines), matching how NVLink bridges
/// and per-GPU PCIe lanes behave.
#[derive(Debug, Clone)]
pub struct Topology {
    links: BTreeMap<(DeviceId, DeviceId), Link>,
    clock: Clock,
    fabric: FabricKind,
}

/// How GPU↔GPU links are wired (§2.2 "future deployments will increase
/// the size of the NVLink domain"; §8 topology-awareness).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FabricKind {
    /// Direct NVLink between every pair (the 2-GPU testbed, DGX-style).
    #[default]
    FullMesh,
    /// NVSwitch / NVLink Switch System: every pair reachable at full
    /// bandwidth through the switch, which adds one hop of setup latency
    /// (NVL72-class racks, up-to-256-GPU domains).
    NvSwitch,
    /// Ring of direct links (cost-reduced topologies): non-adjacent
    /// pairs pay one hop of setup latency per intermediate GPU.
    Ring,
}

impl Topology {
    /// Fully-connected topology for `n_gpus` with the H100 calibration.
    pub fn h100_node(clock: Clock, n_gpus: usize) -> Self {
        Self::custom(clock, n_gpus, LinkModel::nvlink_h100(), LinkModel::pcie5_host())
    }

    pub fn custom(clock: Clock, n_gpus: usize, nvlink: LinkModel, pcie: LinkModel) -> Self {
        Self::with_fabric(clock, n_gpus, nvlink, pcie, FabricKind::FullMesh)
    }

    /// Build a fabric of the given kind. GPU-pair hop counts:
    /// `FullMesh` = 1 everywhere; `NvSwitch` = 2 (GPU→switch→GPU);
    /// `Ring` = ring distance.
    pub fn with_fabric(
        clock: Clock,
        n_gpus: usize,
        nvlink: LinkModel,
        pcie: LinkModel,
        fabric: FabricKind,
    ) -> Self {
        let mut links = BTreeMap::new();
        for i in 0..n_gpus {
            for j in 0..n_gpus {
                if i != j {
                    let hops = Self::hops_for(fabric, n_gpus, i, j);
                    links.insert(
                        (DeviceId::Gpu(i), DeviceId::Gpu(j)),
                        Link {
                            model: nvlink.with_hops(hops),
                            busy_until: 0,
                            bytes_moved: 0,
                            transfers: 0,
                        },
                    );
                }
            }
            for pair in [
                (DeviceId::Gpu(i), DeviceId::Host),
                (DeviceId::Host, DeviceId::Gpu(i)),
            ] {
                links.insert(
                    pair,
                    Link { model: pcie, busy_until: 0, bytes_moved: 0, transfers: 0 },
                );
            }
            // Every GPU also reaches the (optional) CXL memory expander;
            // whether any bytes live there is the node's concern — an
            // unused link costs nothing.
            let cxl = LinkModel::cxl_mem();
            for pair in [(DeviceId::Gpu(i), DeviceId::Cxl), (DeviceId::Cxl, DeviceId::Gpu(i))]
            {
                links.insert(
                    pair,
                    Link { model: cxl, busy_until: 0, bytes_moved: 0, transfers: 0 },
                );
            }
        }
        // The (optional) NVMe cold tier sits behind the host bridge:
        // only the host reaches it directly; GPU↔SSD traffic stages
        // through host DRAM. As with CXL, an unused link costs nothing.
        let ssd = LinkModel::nvme_ssd();
        for pair in [(DeviceId::Host, DeviceId::Ssd), (DeviceId::Ssd, DeviceId::Host)] {
            links.insert(pair, Link { model: ssd, busy_until: 0, bytes_moved: 0, transfers: 0 });
        }
        Self { links, clock, fabric }
    }

    fn hops_for(fabric: FabricKind, n_gpus: usize, i: usize, j: usize) -> u64 {
        match fabric {
            FabricKind::FullMesh => 1,
            FabricKind::NvSwitch => {
                if n_gpus <= 2 {
                    1 // a 2-GPU "domain" is just a bridge
                } else {
                    2
                }
            }
            FabricKind::Ring => {
                let d = i.abs_diff(j);
                d.min(n_gpus - d) as u64
            }
        }
    }

    pub fn fabric(&self) -> FabricKind {
        self.fabric
    }

    /// GPU↔GPU hop distance under this fabric (placement policies use
    /// this for §8 topology-awareness). 0 for i == j.
    pub fn distance(&self, i: usize, j: usize) -> u64 {
        if i == j {
            return 0;
        }
        let n = self
            .links
            .keys()
            .filter_map(|(s, _)| match s {
                DeviceId::Gpu(g) => Some(g + 1),
                DeviceId::Host | DeviceId::Cxl | DeviceId::Ssd => None,
            })
            .max()
            .unwrap_or(0);
        Self::hops_for(self.fabric, n, i, j)
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn link_model(&self, src: DeviceId, dst: DeviceId) -> Option<LinkModel> {
        self.links.get(&(src, dst)).map(|l| l.model)
    }

    /// Unloaded latency estimate (ignores contention) — what a placement
    /// policy would consult.
    pub fn estimate(&self, src: DeviceId, dst: DeviceId, bytes: u64) -> Option<Ns> {
        self.link_model(src, dst).map(|m| m.latency(bytes))
    }

    /// Schedule a contiguous transfer at earliest `earliest`; returns
    /// (start, end). The link serializes transfers FIFO.
    pub fn schedule(
        &mut self,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        earliest: Ns,
    ) -> Option<(Ns, Ns)> {
        let link = self.links.get_mut(&(src, dst))?;
        let start = earliest.max(link.busy_until);
        let end = start + link.model.latency(bytes);
        link.busy_until = end;
        link.bytes_moved += bytes;
        link.transfers += 1;
        Some((start, end))
    }

    /// Bytes moved so far over (src, dst).
    pub fn bytes_moved(&self, src: DeviceId, dst: DeviceId) -> u64 {
        self.links.get(&(src, dst)).map(|l| l.bytes_moved).unwrap_or(0)
    }

    pub fn transfers(&self, src: DeviceId, dst: DeviceId) -> u64 {
        self.links.get(&(src, dst)).map(|l| l.transfers).unwrap_or(0)
    }

    /// When the (src,dst) link becomes idle.
    pub fn busy_until(&self, src: DeviceId, dst: DeviceId) -> Ns {
        self.links.get(&(src, dst)).map(|l| l.busy_until).unwrap_or(0)
    }

    /// Earliest completion of a contiguous (src,dst) transfer issued at
    /// the current virtual time, accounting for FIFO contention:
    /// `max(now, busy_until) + latency(bytes)`. This is the estimate the
    /// deadline-aware prefetch planner consults to decide whether a
    /// background transfer can meet its deadline without delaying demand
    /// traffic (see [`crate::harvest::prefetch`]).
    pub fn earliest_completion(&self, src: DeviceId, dst: DeviceId, bytes: u64) -> Option<Ns> {
        let link = self.links.get(&(src, dst))?;
        Some(self.clock.now().max(link.busy_until) + link.model.latency(bytes))
    }

    /// Like [`Topology::earliest_completion`], but for a *scattered*
    /// transfer split into `bytes.div_ceil(chunk)` descriptors, each
    /// paying the link's per-transfer base latency — the exact cost
    /// model [`crate::memsim::DmaEngine::copy_scattered`] charges.
    /// Admission control must use this for chunked transfers: the
    /// contiguous estimate undershoots, and a prefetch admitted on it
    /// could occupy the link past its deadline.
    pub fn earliest_completion_scattered(
        &self,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        chunk: u64,
    ) -> Option<Ns> {
        let link = self.links.get(&(src, dst))?;
        let n = bytes.div_ceil(chunk.max(1)).max(1);
        // copy_scattered splits into n pieces of bytes/n, the first
        // bytes % n of them one byte larger.
        let per = bytes / n;
        let rem = bytes % n;
        let lat = (n - rem) * link.model.latency(per) + rem * link.model.latency(per + 1);
        Some(self.clock.now().max(link.busy_until) + lat)
    }
}

// ---------------------------------------------------------------------
// Inter-node fabric
// ---------------------------------------------------------------------

/// Link technology class wiring the *nodes* of a cluster together
/// (the intra-node story is [`FabricKind`]; this is the layer above it —
/// see [`crate::cluster`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeFabricKind {
    /// RDMA NICs (400 Gb/s-class, GPUDirect path) — the default for
    /// GPU-cluster deployments.
    #[default]
    Rdma,
    /// Commodity 100 Gb/s Ethernet with a TCP-class stack.
    Ethernet,
}

impl NodeFabricKind {
    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "rdma" => Ok(NodeFabricKind::Rdma),
            "ethernet" | "eth" => Ok(NodeFabricKind::Ethernet),
            other => anyhow::bail!("unknown node fabric `{other}` (rdma | ethernet)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NodeFabricKind::Rdma => "rdma",
            NodeFabricKind::Ethernet => "ethernet",
        }
    }

    /// The link model for one direction of a node pair.
    pub fn link_model(&self) -> LinkModel {
        match self {
            NodeFabricKind::Rdma => LinkModel::rdma_nic(),
            NodeFabricKind::Ethernet => LinkModel::ethernet_100g(),
        }
    }
}

/// The inter-node network: one directed [`Link`]-modelled NIC path per
/// node pair, FIFO contention per direction, same analytic
/// latency/bandwidth model as the intra-node links.
///
/// Unlike [`Topology`] this carries no clock — each node of a cluster
/// advances its own virtual clock, so callers pass the earliest start
/// explicitly and sequence completions themselves (see
/// [`crate::cluster::Cluster`]).
#[derive(Debug, Clone)]
pub struct NodeFabric {
    links: BTreeMap<(usize, usize), Link>,
    kind: NodeFabricKind,
}

impl NodeFabric {
    /// Full-mesh NIC wiring between `n_nodes` nodes.
    pub fn new(n_nodes: usize, kind: NodeFabricKind) -> Self {
        let model = kind.link_model();
        let mut links = BTreeMap::new();
        for i in 0..n_nodes {
            for j in 0..n_nodes {
                if i != j {
                    links.insert(
                        (i, j),
                        Link { model, busy_until: 0, bytes_moved: 0, transfers: 0 },
                    );
                }
            }
        }
        Self { links, kind }
    }

    pub fn kind(&self) -> NodeFabricKind {
        self.kind
    }

    /// Unloaded latency of a `bytes`-sized transfer between two nodes.
    pub fn estimate(&self, src: usize, dst: usize, bytes: u64) -> Option<Ns> {
        self.links.get(&(src, dst)).map(|l| l.model.latency(bytes))
    }

    /// Schedule a transfer at earliest `earliest`; returns (start, end).
    /// Each direction of a node pair serializes FIFO; distinct pairs are
    /// independent NIC queues.
    pub fn schedule(
        &mut self,
        src: usize,
        dst: usize,
        bytes: u64,
        earliest: Ns,
    ) -> Option<(Ns, Ns)> {
        let link = self.links.get_mut(&(src, dst))?;
        let start = earliest.max(link.busy_until);
        let end = start + link.model.latency(bytes);
        link.busy_until = end;
        link.bytes_moved += bytes;
        link.transfers += 1;
        Some((start, end))
    }

    /// When the (src,dst) direction becomes idle.
    pub fn busy_until(&self, src: usize, dst: usize) -> Ns {
        self.links.get(&(src, dst)).map(|l| l.busy_until).unwrap_or(0)
    }

    pub fn bytes_moved(&self, src: usize, dst: usize) -> u64 {
        self.links.get(&(src, dst)).map(|l| l.bytes_moved).unwrap_or(0)
    }

    pub fn transfers(&self, src: usize, dst: usize) -> u64 {
        self.links.get(&(src, dst)).map(|l| l.transfers).unwrap_or(0)
    }

    /// Total bytes moved over the whole fabric (all directions).
    pub fn total_bytes_moved(&self) -> u64 {
        self.links.values().map(|l| l.bytes_moved).sum()
    }

    /// Total transfers over the whole fabric.
    pub fn total_transfers(&self) -> u64 {
        self.links.values().map(|l| l.transfers).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: u64 = 1024 * 1024;

    #[test]
    fn latency_monotone_in_size() {
        let m = LinkModel::nvlink_h100();
        let mut prev = 0;
        for sz in [0u64, 1024, MIB, 16 * MIB, 256 * MIB] {
            let l = m.latency(sz);
            assert!(l >= prev, "latency must be monotone");
            prev = l;
        }
    }

    #[test]
    fn small_transfers_latency_dominated() {
        // In the saturating model, tiny transfers cost ~base + half_sat/peak
        // (a constant floor) regardless of size: 4 KiB and 64 KiB must be
        // within ~15% of each other, far from linear-in-bytes scaling.
        let m = LinkModel::pcie5_host();
        let a = m.latency(4 * 1024) as f64;
        let b = m.latency(64 * 1024) as f64;
        assert!(b / a < 1.15, "a={a} b={b}");
        let floor = m.base_latency_ns as f64 + m.half_sat_bytes / m.peak_bw_bytes_per_ns;
        assert!(a <= floor + 1_000.0, "a={a} floor={floor}");
    }

    #[test]
    fn large_transfers_near_peak_bw() {
        let m = LinkModel::nvlink_h100();
        let bytes = 512 * MIB;
        let l = m.latency(bytes);
        let ideal = (bytes as f64 / m.peak_bw_bytes_per_ns) as Ns;
        // within 5% of the bandwidth-only time (+base)
        assert!(l < ideal + ideal / 20 + m.base_latency_ns, "l={l} ideal={ideal}");
    }

    #[test]
    fn fig3_speedup_band() {
        // The Fig. 3 calibration target: contiguous expert-sized copies
        // must see 7–10× NVLink-over-PCIe advantage.
        let nv = LinkModel::nvlink_h100();
        let pcie = LinkModel::pcie5_host();
        for (bytes, lo, hi) in [
            (17 * MIB, 7.0, 8.5),   // Phi-tiny-class expert
            (157 * MIB, 8.5, 9.6),  // Phi-3.5-class expert
            (352 * MIB, 9.0, 9.8),  // Mixtral-class expert
        ] {
            let ratio = pcie.latency(bytes) as f64 / nv.latency(bytes) as f64;
            assert!(
                (lo..=hi).contains(&ratio),
                "bytes={bytes}: ratio={ratio:.2} not in [{lo}, {hi}]"
            );
        }
    }

    #[test]
    fn contention_serializes_fifo() {
        let clock = Clock::new();
        let mut t = Topology::h100_node(clock, 2);
        let (s1, e1) = t.schedule(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, 0).unwrap();
        let (s2, e2) = t.schedule(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, 0).unwrap();
        assert_eq!(s1, 0);
        assert_eq!(s2, e1);
        assert!(e2 > e1);
    }

    #[test]
    fn distinct_links_independent() {
        let clock = Clock::new();
        let mut t = Topology::h100_node(clock, 2);
        let (_, e1) = t.schedule(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, 0).unwrap();
        let (s2, _) = t.schedule(DeviceId::Gpu(1), DeviceId::Gpu(0), MIB, 0).unwrap();
        assert_eq!(s2, 0, "reverse direction is its own link");
        assert!(e1 > 0);
    }

    #[test]
    fn no_link_between_same_device() {
        let clock = Clock::new();
        let mut t = Topology::h100_node(clock, 2);
        assert!(t.schedule(DeviceId::Gpu(0), DeviceId::Gpu(0), MIB, 0).is_none());
        assert!(t.estimate(DeviceId::Gpu(0), DeviceId::Gpu(0), MIB).is_none());
    }

    #[test]
    fn nvswitch_adds_one_hop_of_latency() {
        let mesh = Topology::with_fabric(
            Clock::new(),
            8,
            LinkModel::nvlink_h100(),
            LinkModel::pcie5_host(),
            FabricKind::FullMesh,
        );
        let sw = Topology::with_fabric(
            Clock::new(),
            8,
            LinkModel::nvlink_h100(),
            LinkModel::pcie5_host(),
            FabricKind::NvSwitch,
        );
        let a = mesh.estimate(DeviceId::Gpu(0), DeviceId::Gpu(7), MIB).unwrap();
        let b = sw.estimate(DeviceId::Gpu(0), DeviceId::Gpu(7), MIB).unwrap();
        assert_eq!(b - a, LinkModel::nvlink_h100().base_latency_ns);
        // still far cheaper than PCIe
        let h = sw.estimate(DeviceId::Host, DeviceId::Gpu(7), MIB).unwrap();
        assert!(b < h);
    }

    #[test]
    fn ring_distance_scales_latency() {
        let ring = Topology::with_fabric(
            Clock::new(),
            8,
            LinkModel::nvlink_h100(),
            LinkModel::pcie5_host(),
            FabricKind::Ring,
        );
        assert_eq!(ring.distance(0, 1), 1);
        assert_eq!(ring.distance(0, 4), 4);
        assert_eq!(ring.distance(0, 7), 1, "ring wraps");
        let near = ring.estimate(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB).unwrap();
        let far = ring.estimate(DeviceId::Gpu(0), DeviceId::Gpu(4), MIB).unwrap();
        assert!(far > near);
        assert_eq!(
            far - near,
            3 * LinkModel::nvlink_h100().base_latency_ns,
            "3 extra hops of setup latency"
        );
    }

    #[test]
    fn two_gpu_nvswitch_degenerates_to_bridge() {
        let sw = Topology::with_fabric(
            Clock::new(),
            2,
            LinkModel::nvlink_h100(),
            LinkModel::pcie5_host(),
            FabricKind::NvSwitch,
        );
        let mesh = Topology::h100_node(Clock::new(), 2);
        assert_eq!(
            sw.estimate(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB),
            mesh.estimate(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB)
        );
    }

    #[test]
    fn cxl_between_peer_and_host() {
        let nv = LinkModel::nvlink_h100();
        let cxl = LinkModel::cxl_mem();
        let pcie = LinkModel::pcie5_host();
        for bytes in [MIB, 64 * MIB, 336 * MIB] {
            assert!(nv.latency(bytes) < cxl.latency(bytes));
            assert!(cxl.latency(bytes) < pcie.latency(bytes));
        }
    }

    #[test]
    fn earliest_completion_accounts_for_queue() {
        let clock = Clock::new();
        let mut t = Topology::h100_node(clock, 2);
        let idle = t.earliest_completion(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB).unwrap();
        assert_eq!(idle, LinkModel::nvlink_h100().latency(MIB));
        // queue a transfer: the next one completes after it
        let (_, e1) = t.schedule(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB, 0).unwrap();
        let queued = t.earliest_completion(DeviceId::Gpu(0), DeviceId::Gpu(1), MIB).unwrap();
        assert_eq!(queued, e1 + LinkModel::nvlink_h100().latency(MIB));
        // unknown link pair
        assert!(t.earliest_completion(DeviceId::Gpu(0), DeviceId::Gpu(0), MIB).is_none());
    }

    #[test]
    fn scattered_completion_matches_scattered_copy_cost() {
        let t = Topology::h100_node(Clock::new(), 2);
        let (src, dst) = (DeviceId::Gpu(1), DeviceId::Gpu(0));
        let bytes = 9 * MIB;
        let chunk = 4 * MIB;
        let scattered = t.earliest_completion_scattered(src, dst, bytes, chunk).unwrap();
        let contiguous = t.earliest_completion(src, dst, bytes).unwrap();
        assert!(
            scattered > contiguous,
            "per-chunk overheads must be charged: {scattered} <= {contiguous}"
        );
        // exact agreement with what the DMA engine would schedule
        let m = LinkModel::nvlink_h100(); // 1 hop on the 2-GPU mesh
        let n = bytes.div_ceil(chunk); // 3 chunks of 3 MiB
        assert_eq!(scattered, n * m.latency(bytes / n));
        // degenerate single chunk equals the contiguous estimate
        assert_eq!(
            t.earliest_completion_scattered(src, dst, MIB, chunk),
            t.earliest_completion(src, dst, MIB)
        );
    }

    #[test]
    fn cxl_links_wired_per_gpu() {
        let mut t = Topology::h100_node(Clock::new(), 2);
        for g in 0..2 {
            assert!(t.link_model(DeviceId::Gpu(g), DeviceId::Cxl).is_some());
            assert!(t.link_model(DeviceId::Cxl, DeviceId::Gpu(g)).is_some());
        }
        // no direct host<->cxl path — traffic staged through a GPU
        assert!(t.link_model(DeviceId::Host, DeviceId::Cxl).is_none());
        // tier ordering holds on the wired links too
        let nv = t.estimate(DeviceId::Gpu(1), DeviceId::Gpu(0), MIB).unwrap();
        let cxl = t.estimate(DeviceId::Cxl, DeviceId::Gpu(0), MIB).unwrap();
        let host = t.estimate(DeviceId::Host, DeviceId::Gpu(0), MIB).unwrap();
        assert!(nv < cxl && cxl < host, "nv={nv} cxl={cxl} host={host}");
        // and the cxl link schedules like any other
        let (s, e) = t.schedule(DeviceId::Cxl, DeviceId::Gpu(0), MIB, 0).unwrap();
        assert_eq!(s, 0);
        assert_eq!(e, cxl);
    }

    #[test]
    fn ssd_link_wired_behind_host_only() {
        let mut t = Topology::h100_node(Clock::new(), 2);
        assert!(t.link_model(DeviceId::Host, DeviceId::Ssd).is_some());
        assert!(t.link_model(DeviceId::Ssd, DeviceId::Host).is_some());
        // no direct GPU<->SSD or CXL<->SSD path — traffic stages through host
        for g in 0..2 {
            assert!(t.link_model(DeviceId::Gpu(g), DeviceId::Ssd).is_none());
            assert!(t.link_model(DeviceId::Ssd, DeviceId::Gpu(g)).is_none());
        }
        assert!(t.link_model(DeviceId::Cxl, DeviceId::Ssd).is_none());
        // the SSD rung is strictly the slowest link class in the node
        let host = t.estimate(DeviceId::Host, DeviceId::Gpu(0), MIB).unwrap();
        let ssd = t.estimate(DeviceId::Ssd, DeviceId::Host, MIB).unwrap();
        assert!(ssd > host, "ssd={ssd} host={host}");
        // and it schedules like any other link
        let (s, e) = t.schedule(DeviceId::Ssd, DeviceId::Host, MIB, 0).unwrap();
        assert_eq!(s, 0);
        assert_eq!(e, ssd);
    }

    #[test]
    fn node_fabric_orders_between_nvlink_and_pcie_setup() {
        // An RDMA hop is slower than NVLink for expert-sized payloads but
        // competitive with (and for large payloads similar to) PCIe host
        // paging; Ethernet is strictly the slowest class.
        let nv = LinkModel::nvlink_h100();
        let rdma = LinkModel::rdma_nic();
        let eth = LinkModel::ethernet_100g();
        for bytes in [MIB, 64 * MIB, 352 * MIB] {
            assert!(nv.latency(bytes) < rdma.latency(bytes));
            assert!(rdma.latency(bytes) < eth.latency(bytes));
        }
    }

    #[test]
    fn node_fabric_schedules_fifo_per_direction() {
        let mut f = NodeFabric::new(3, NodeFabricKind::Rdma);
        let (s1, e1) = f.schedule(0, 1, MIB, 0).unwrap();
        let (s2, e2) = f.schedule(0, 1, MIB, 0).unwrap();
        assert_eq!(s1, 0);
        assert_eq!(s2, e1, "same direction serializes");
        // reverse direction and distinct pairs are independent
        let (s3, _) = f.schedule(1, 0, MIB, 0).unwrap();
        let (s4, _) = f.schedule(0, 2, MIB, 0).unwrap();
        assert_eq!(s3, 0);
        assert_eq!(s4, 0);
        assert_eq!(f.busy_until(0, 1), e2);
        assert_eq!(f.bytes_moved(0, 1), 2 * MIB);
        assert_eq!(f.transfers(0, 1), 2);
        assert_eq!(f.total_bytes_moved(), 4 * MIB);
        assert_eq!(f.total_transfers(), 4);
        // no self link
        assert!(f.schedule(1, 1, MIB, 0).is_none());
        assert!(f.estimate(1, 1, MIB).is_none());
    }

    #[test]
    fn node_fabric_kind_parse_roundtrip() {
        for k in [NodeFabricKind::Rdma, NodeFabricKind::Ethernet] {
            assert_eq!(NodeFabricKind::parse(k.name()).unwrap(), k);
        }
        assert!(NodeFabricKind::parse("carrier-pigeon").is_err());
        let rdma = NodeFabric::new(2, NodeFabricKind::Rdma);
        let eth = NodeFabric::new(2, NodeFabricKind::Ethernet);
        assert!(rdma.estimate(0, 1, MIB).unwrap() < eth.estimate(0, 1, MIB).unwrap());
    }

    #[test]
    fn metrics_accumulate() {
        let clock = Clock::new();
        let mut t = Topology::h100_node(clock, 2);
        t.schedule(DeviceId::Gpu(0), DeviceId::Host, 100, 0).unwrap();
        t.schedule(DeviceId::Gpu(0), DeviceId::Host, 200, 0).unwrap();
        assert_eq!(t.bytes_moved(DeviceId::Gpu(0), DeviceId::Host), 300);
        assert_eq!(t.transfers(DeviceId::Gpu(0), DeviceId::Host), 2);
    }
}
