//! Background collective traffic — the §7 limitation the paper does not
//! evaluate: "scenarios with significant NVLink congestion from
//! concurrent model-parallel collectives or other tenants, which could
//! reduce the bandwidth available for paging".
//!
//! A [`CollectiveTraffic`] generator pre-schedules periodic transfers on
//! the GPU↔GPU links (ring all-reduce or all-to-all patterns). Because
//! [`Topology::schedule`] serializes per-link FIFO, Harvest's own copies
//! then queue behind the collective's, exactly like DMA engines sharing
//! an NVLink bridge.

use super::clock::Ns;
use super::interconnect::{DeviceId, Topology};

/// Communication pattern of the background job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectivePattern {
    /// Ring all-reduce: GPU i → (i+1) mod n, every step.
    RingAllReduce,
    /// All-to-all (MoE dispatch-style): every ordered pair, every step.
    AllToAll,
}

/// A periodic background collective on the node's GPUs.
#[derive(Debug, Clone)]
pub struct CollectiveTraffic {
    pub pattern: CollectivePattern,
    /// GPUs participating (e.g. a tensor-parallel group).
    pub gpus: Vec<usize>,
    /// Bytes each participant sends per step (per destination for
    /// all-to-all).
    pub bytes_per_step: u64,
    /// Virtual time between step starts.
    pub period_ns: Ns,
    /// Next step start time (advanced by [`Self::inject_until`]).
    next_step: Ns,
    /// Totals for reporting.
    pub steps_injected: u64,
    pub bytes_injected: u64,
}

impl CollectiveTraffic {
    pub fn new(
        pattern: CollectivePattern,
        gpus: Vec<usize>,
        bytes_per_step: u64,
        period_ns: Ns,
    ) -> Self {
        assert!(gpus.len() >= 2, "collective needs >= 2 GPUs");
        assert!(period_ns > 0);
        Self {
            pattern,
            gpus,
            bytes_per_step,
            period_ns,
            next_step: 0,
            steps_injected: 0,
            bytes_injected: 0,
        }
    }

    /// Fast-forward the schedule so the next step starts no earlier
    /// than `t` (never rewinds). Used by tenant actors created mid-run:
    /// without it the first `inject_until` would back-fill steps from
    /// virtual time 0.
    pub fn skip_to(&mut self, t: Ns) {
        if self.next_step < t {
            let missed = (t - self.next_step).div_ceil(self.period_ns);
            self.next_step += missed * self.period_ns;
        }
    }

    /// Mean bytes/sec this collective pushes onto each participating
    /// link direction (for sizing experiments).
    pub fn per_link_demand_bytes_per_sec(&self) -> f64 {
        self.bytes_per_step as f64 / (self.period_ns as f64 / 1e9)
    }

    /// Schedule all collective steps with start times in `[next, until)`
    /// onto the topology. Call before (or interleaved with) the
    /// foreground workload; FIFO links then model the contention.
    pub fn inject_until(&mut self, topo: &mut Topology, until: Ns) {
        while self.next_step < until {
            let t = self.next_step;
            match self.pattern {
                CollectivePattern::RingAllReduce => {
                    let n = self.gpus.len();
                    for (idx, &g) in self.gpus.iter().enumerate() {
                        let dst = self.gpus[(idx + 1) % n];
                        topo.schedule(DeviceId::Gpu(g), DeviceId::Gpu(dst), self.bytes_per_step, t);
                        self.bytes_injected += self.bytes_per_step;
                    }
                }
                CollectivePattern::AllToAll => {
                    for &a in &self.gpus {
                        for &b in &self.gpus {
                            if a != b {
                                topo.schedule(
                                    DeviceId::Gpu(a),
                                    DeviceId::Gpu(b),
                                    self.bytes_per_step,
                                    t,
                                );
                                self.bytes_injected += self.bytes_per_step;
                            }
                        }
                    }
                }
            }
            self.steps_injected += 1;
            self.next_step += self.period_ns;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::{Clock, NodeSpec, SimNode};

    const MIB: u64 = 1 << 20;

    #[test]
    fn ring_schedules_one_transfer_per_participant_per_step() {
        let clock = Clock::new();
        let mut topo = Topology::h100_node(clock, 4);
        let mut c =
            CollectiveTraffic::new(CollectivePattern::RingAllReduce, vec![0, 1, 2, 3], MIB, 1_000);
        c.inject_until(&mut topo, 10_000);
        assert_eq!(c.steps_injected, 10);
        assert_eq!(topo.transfers(DeviceId::Gpu(0), DeviceId::Gpu(1)), 10);
        assert_eq!(topo.transfers(DeviceId::Gpu(3), DeviceId::Gpu(0)), 10);
        assert_eq!(topo.transfers(DeviceId::Gpu(0), DeviceId::Gpu(2)), 0, "ring skips non-neighbours");
    }

    #[test]
    fn all_to_all_covers_every_pair() {
        let clock = Clock::new();
        let mut topo = Topology::h100_node(clock, 3);
        let mut c =
            CollectiveTraffic::new(CollectivePattern::AllToAll, vec![0, 1, 2], MIB, 1_000);
        c.inject_until(&mut topo, 1);
        for a in 0..3 {
            for b in 0..3 {
                if a != b {
                    assert_eq!(topo.transfers(DeviceId::Gpu(a), DeviceId::Gpu(b)), 1);
                }
            }
        }
    }

    #[test]
    fn congestion_delays_foreground_copy() {
        // Same copy with and without a heavy collective on the link.
        let quiet = {
            let mut node = SimNode::new(NodeSpec::h100x2());
            node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), 64 * MIB, None).duration()
        };
        let congested = {
            let mut node = SimNode::new(NodeSpec::h100x2());
            let mut c = CollectiveTraffic::new(
                CollectivePattern::RingAllReduce,
                vec![0, 1],
                256 * MIB,
                100_000,
            );
            c.inject_until(&mut node.topo, 1_000_000);
            let ev = node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), 64 * MIB, None);
            ev.end // includes queueing behind the collective
        };
        assert!(
            congested > quiet,
            "congested end {congested} should exceed quiet duration {quiet}"
        );
    }

    #[test]
    fn inject_is_incremental() {
        let clock = Clock::new();
        let mut topo = Topology::h100_node(clock, 2);
        let mut c =
            CollectiveTraffic::new(CollectivePattern::RingAllReduce, vec![0, 1], MIB, 1_000);
        c.inject_until(&mut topo, 5_000);
        let five = c.steps_injected;
        c.inject_until(&mut topo, 5_000);
        assert_eq!(c.steps_injected, five, "no double injection");
        c.inject_until(&mut topo, 10_000);
        assert_eq!(c.steps_injected, 10);
    }

    #[test]
    fn demand_accounting() {
        let c = CollectiveTraffic::new(
            CollectivePattern::RingAllReduce,
            vec![0, 1],
            100 * MIB,
            1_000_000, // 1 ms
        );
        let d = c.per_link_demand_bytes_per_sec();
        assert!((d - 100.0 * MIB as f64 * 1000.0).abs() / d < 1e-9);
    }
}
