//! `harvest` — the launcher CLI.
//!
//! ```text
//! harvest serve    --preset paper-moe | --config deploy.toml [--set key=value ...]
//!                  [--trace out.json] [--report report.json]
//! harvest analyze  --trace out.json [--report report.json] [--top K]
//! harvest guard    [--dir DIR] [--threshold FRAC]
//! harvest presets  [--dump NAME]
//! harvest models
//! harvest trace    [--machines N] [--snapshots-per-machine N]
//! harvest transfer [--chunk-mib X ...]
//! harvest help | version
//! ```
//!
//! `serve` materializes a [`harvest::config::DeploymentConfig`] and runs
//! the configured workload: the §4 MoE expert-offload pipeline, the §5
//! KV-offload decode loop, or the end-to-end real-PJRT serve on the AOT
//! tiny model. Arg parsing is hand-rolled (clap is not vendored on this
//! image).

use anyhow::{anyhow, bail, Context, Result};
use harvest::config::{find_preset, presets, DeploymentConfig, WorkloadKind};
use harvest::harvest::HarvestRuntime;
use harvest::memsim::{DeviceId, SimNode};
use harvest::moe::config::{KV_MODELS, MOE_MODELS};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{CgoPipe, ExpertRebalancer, RouterSim};
use harvest::obs::{self, MetricsRegistry};
use harvest::runtime::ModelRuntime;
use harvest::server::{RealEngine, SimEngine, SimEngineConfig, WorkloadGen};
use harvest::trace::{ClusterTrace, TraceSpec};
use harvest::util::json::Json;
use harvest::util::{fmt_bytes, fmt_ns};
use std::path::Path;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "serve" => cmd_serve(rest),
        "analyze" => cmd_analyze(rest),
        "guard" => cmd_guard(rest),
        "presets" => cmd_presets(rest),
        "models" => cmd_models(),
        "trace" => cmd_trace(rest),
        "transfer" => cmd_transfer(rest),
        "version" | "--version" | "-V" => {
            println!("harvest {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command `{other}`")
        }
    }
}

fn print_help() {
    println!(
        "harvest — opportunistic peer-to-peer GPU caching for LLM inference

USAGE:
  harvest serve    --preset NAME | --config FILE [--set key=value ...] [--trace FILE]
                   --trace writes a Perfetto-loadable trace (see [obs] config)
                   --report FILE arms per-request latency attribution and writes
                   the registry + attribution report document
  harvest analyze  --trace FILE [--report FILE] [--top K]
                   offline latency forensics: per-phase rollups, critical path,
                   causal attribution table, top-K slow-request breakdowns
  harvest guard    [--dir DIR] [--threshold FRAC]   perf-trajectory regression
                   gate over the committed BENCH_*.json trajectories
  harvest presets  [--dump NAME]      list (or dump) deployment presets
  harvest models                      print the Table-1 / §5.3 registries
  harvest trace    [--machines N] [--snapshots-per-machine N]
  harvest transfer [--chunk-mib X]    GPU<->GPU vs CPU<->GPU latency (Fig. 3)
  harvest help | version"
    );
}

/// Pull `--flag value` out of an argument list.
fn take_opt(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

/// All occurrences of `--flag value`.
fn take_all(args: &[String], flag: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == flag {
            if let Some(v) = args.get(i + 1) {
                out.push(v.clone());
                i += 1;
            }
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------

fn load_config(args: &[String]) -> Result<DeploymentConfig> {
    let base = if let Some(name) = take_opt(args, "--preset") {
        find_preset(&name).ok_or_else(|| {
            anyhow!(
                "unknown preset `{name}` (have: {})",
                presets().iter().map(|p| p.name.clone()).collect::<Vec<_>>().join(", ")
            )
        })?
    } else if let Some(path) = take_opt(args, "--config") {
        DeploymentConfig::from_file(Path::new(&path))?
    } else {
        DeploymentConfig::default()
    };
    // `--set section.key=value` overrides on top of the base, applied by
    // re-serializing and patching the TOML (keeps one parse/validate path).
    let overrides = take_all(args, "--set");
    if overrides.is_empty() {
        return Ok(base);
    }
    let mut text = base.to_toml();
    for ov in overrides {
        let (path, value) = ov
            .split_once('=')
            .ok_or_else(|| anyhow!("--set expects key=value, got `{ov}`"))?;
        text = patch_toml(&text, path.trim(), value.trim())?;
    }
    DeploymentConfig::from_toml(&text)
}

/// Replace (or append) `section.key = value` in TOML-subset text.
fn patch_toml(text: &str, path: &str, value: &str) -> Result<String> {
    let (section, key) = match path.rsplit_once('.') {
        Some((s, k)) => (s.to_string(), k.to_string()),
        None => (String::new(), path.to_string()),
    };
    // Quote string values that are not numbers/bools/arrays.
    let rendered = if value.parse::<f64>().is_ok()
        || value == "true"
        || value == "false"
        || value.starts_with('[')
        || value.starts_with('"')
    {
        value.to_string()
    } else {
        format!("\"{value}\"")
    };
    let mut out = Vec::new();
    let mut cur_section = String::new();
    let mut replaced = false;
    for line in text.lines() {
        let trimmed = line.trim();
        if let Some(name) = trimmed.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            // entering a new section: if we were in the target section and
            // never found the key, inject it before leaving.
            if !replaced && cur_section == section {
                out.push(format!("{key} = {rendered}"));
                replaced = true;
            }
            cur_section = name.trim().to_string();
            out.push(line.to_string());
            continue;
        }
        if !replaced && cur_section == section {
            if let Some((k, _)) = trimmed.split_once('=') {
                if k.trim() == key {
                    out.push(format!("{key} = {rendered}"));
                    replaced = true;
                    continue;
                }
            }
        }
        out.push(line.to_string());
    }
    if !replaced {
        if cur_section != section {
            out.push(format!("[{section}]"));
        }
        out.push(format!("{key} = {rendered}"));
    }
    Ok(out.join("\n") + "\n")
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cfg = load_config(args)?;
    let trace_path = take_opt(args, "--trace");
    if trace_path.is_some() {
        obs::trace::enable(cfg.obs_ring_cap);
        if cfg.obs_flight {
            obs::flight::arm(obs::FlightConfig {
                shed_burst: cfg.obs_shed_burst as u64,
                ..Default::default()
            });
        }
    }
    if cfg.obs_profile {
        obs::profile::enable();
    }
    println!("deployment `{}` ({} workload)", cfg.name, cfg.workload.name());
    println!("  node: {} GPUs x {} GiB HBM", cfg.n_gpus, cfg.hbm_gib);
    if cfg.nodes > 1 {
        println!(
            "  cluster: {} nodes, {} routing, {} fabric",
            cfg.nodes,
            cfg.router_policy.name(),
            cfg.node_fabric.name()
        );
    }
    println!(
        "  harvest: {} (victim={:?}, reserve={} GiB, mig={:?})",
        if cfg.harvest_enabled { "on" } else { "off" },
        cfg.victim_policy,
        cfg.reserve_gib,
        cfg.mig_cache_gib
    );
    let report_path = take_opt(args, "--report");
    if report_path.is_some() && !matches!(cfg.workload, WorkloadKind::KvOffload) {
        bail!("--report (latency attribution) is only supported for the kv workload");
    }
    let result = match cfg.workload {
        WorkloadKind::MoeOffload => serve_moe(&cfg),
        WorkloadKind::KvOffload => serve_kv(&cfg, report_path.as_deref()),
        WorkloadKind::RealServe => serve_real(&cfg),
    };
    if let Some(path) = trace_path {
        let dropped = obs::trace::dropped();
        let events = obs::trace::take();
        std::fs::write(&path, obs::trace::to_chrome_json(&events).to_string())
            .with_context(|| format!("writing trace to {path}"))?;
        println!("  trace: {} events -> {path} ({dropped} evicted from ring)", events.len());
        let dumps = obs::flight::take_dumps();
        if !dumps.is_empty() {
            let fpath = format!("{path}.flight.json");
            std::fs::write(&fpath, obs::flight::dumps_to_json(&dumps).to_string())
                .with_context(|| format!("writing flight dumps to {fpath}"))?;
            println!("  flight: {} incident dumps -> {fpath}", dumps.len());
        }
        obs::flight::disarm();
        obs::trace::disable();
    }
    if cfg.obs_profile {
        println!("  profile: {}", obs::profile::snapshot().to_json().to_string());
        obs::profile::disable();
    }
    result
}

fn serve_moe(cfg: &DeploymentConfig) -> Result<()> {
    let model = harvest::moe::config::find_moe_model(&cfg.moe_model)
        .ok_or_else(|| anyhow!("unknown MoE model `{}`", cfg.moe_model))?;
    let mut hr = HarvestRuntime::new(SimNode::new(cfg.node_spec()), cfg.harvest_config());
    let pipe = CgoPipe {
        model,
        micro_batch_tokens: cfg.micro_batch_tokens,
        n_micro_batches: cfg.n_micro_batches,
        cost: Default::default(),
    };
    let mut router = RouterSim::new(model, model.n_layers as usize, cfg.seed);
    let mut reb = ExpertRebalancer::new(model, 0, cfg.offload_fraction);
    let tier = if cfg.harvest_enabled {
        let migrated = reb.rebalance(&mut hr, usize::MAX);
        println!(
            "  rebalancer: migrated {migrated} experts to peer HBM ({})",
            fmt_bytes(migrated as u64 * model.expert_bytes())
        );
        OffloadTier::Harvest
    } else {
        OffloadTier::Cpu
    };
    println!(
        "  model {}: {} layers, {} experts (top-{}), expert = {}",
        model.name,
        model.n_layers,
        model.n_experts,
        model.top_k,
        fmt_bytes(model.expert_bytes())
    );
    // Warmup (the §4.4 bench generates 50 warmup tokens).
    let _ = pipe.decode_many(&mut router, &mut reb, &mut hr, tier, 2);
    let stats = pipe.decode_many(&mut router, &mut reb, &mut hr, tier, cfg.max_new_tokens as usize);
    println!(
        "  decode: {} tokens in {} -> {:.0} tok/s",
        stats.tokens,
        fmt_ns(stats.pass_ns),
        stats.tokens_per_sec()
    );
    println!(
        "  fetches: local {}, peer {}, host {} | stalls {}",
        stats.fetches_local,
        stats.fetches_peer,
        stats.fetches_host,
        fmt_ns(stats.stall_ns)
    );
    Ok(())
}

fn serve_kv(cfg: &DeploymentConfig, report_path: Option<&str>) -> Result<()> {
    if cfg.nodes > 1 {
        return serve_kv_cluster(cfg, report_path);
    }
    let mut hr = HarvestRuntime::with_policy(
        SimNode::new(cfg.node_spec()),
        cfg.harvest_config(),
        cfg.placement_spec()?.build(),
    );
    let kv = cfg.kv_config()?;
    let scheduler = cfg.scheduler_spec()?.build();
    let mut engine_cfg = SimEngineConfig::new(kv, cfg.decode_slots, cfg.max_running);
    if cfg.obs_attribution || report_path.is_some() {
        engine_cfg = engine_cfg.with_attribution();
    }
    let admission = cfg.admission_policy()?;
    if let Some(acfg) = admission.admission_config() {
        engine_cfg = engine_cfg.with_admission(acfg);
    }
    let mut engine = SimEngine::new(engine_cfg, scheduler, 0);
    if let Some(fleet) = cfg.tenant_fleet() {
        let mix = cfg.node0_tenant_mix();
        println!(
            "  tenants: {} actors ({} training / {} inference / {} batch, {} priority bursts)",
            fleet.len(),
            mix.training,
            mix.inference,
            mix.batch,
            mix.batch_priority.name()
        );
        engine = engine.with_tenants(fleet);
    }
    let requests = WorkloadGen::new(cfg.workload_spec()).generate();
    println!(
        "  kv model {}: {} per token, block = {} tokens, pool = {} blocks",
        kv.model.name,
        fmt_bytes(kv.model.kv_bytes_per_token()),
        kv.block_tokens,
        kv.local_capacity_blocks
    );
    let report = engine.run(&mut hr, requests);
    println!("  scheduler {}, admission {}", report.scheduler, admission.name());
    if let Some(t) = &report.tenant {
        println!(
            "  tenants: {} held, {} injected, {} lease yields ({} demotions), {} denied",
            fmt_bytes(t.held_bytes()),
            fmt_bytes(t.traffic_bytes()),
            t.broker.lease_yields,
            hr.demotions,
            t.denied()
        );
    }
    // One registry snapshot over every stat surface — serve's single
    // machine-readable output, and the tree the human summary renders
    // from (shared with the cluster path so the two cannot drift).
    let mut reg = MetricsRegistry::new();
    report.metrics.register(&mut reg, "serve");
    report.kv_stats.register(&mut reg, "kv");
    if let Some(a) = &report.admission {
        a.register(&mut reg, "admission");
    }
    if let Some(t) = &report.tenant {
        t.broker.register(&mut reg, "tenant.broker");
    }
    hr.monitor().register(&mut reg, "harvest.tiers");
    harvest::cluster::TierLedger::snapshot(&hr).register(&mut reg, "ledger");
    let pricing = obs::TierPricing::default();
    obs::harvest_economics(&report.kv_stats, &pricing).register(&mut reg, "economics");
    if let Some(a) = &report.attribution {
        a.register(&mut reg, "attrib");
    }
    print_serve_summary(&reg);
    println!("{}", reg.to_json().to_string());
    if let Some(path) = report_path {
        write_report_file(path, &reg, report.attribution.as_ref())?;
    }
    Ok(())
}

fn serve_kv_cluster(cfg: &DeploymentConfig, report_path: Option<&str>) -> Result<()> {
    use harvest::cluster::Cluster;
    let kv = cfg.kv_config()?;
    println!(
        "  kv model {}: {} per token, block = {} tokens, pool = {} blocks/node",
        kv.model.name,
        fmt_bytes(kv.model.kv_bytes_per_token()),
        kv.block_tokens,
        kv.local_capacity_blocks
    );
    let mut engine = SimEngineConfig::new(kv, cfg.decode_slots, cfg.max_running);
    if cfg.obs_attribution || report_path.is_some() {
        engine = engine.with_attribution();
    }
    let mut cluster = Cluster::new(&cfg.cluster_spec(), engine, cfg.scheduler_spec()?);
    let requests = WorkloadGen::new(cfg.workload_spec()).generate();
    let report = cluster.run(requests);
    println!(
        "  routing: {} | {} router shed | prefix migrations {} ({} over the {} fabric)",
        report.router_policy,
        report.stats.shed,
        report.stats.prefix_migrations,
        fmt_bytes(report.stats.migrated_bytes),
        cluster.fabric().kind().name()
    );
    println!(
        "  admission {} (node sheds {})",
        cfg.admission_policy()?.name(),
        report.stats.node_shed
    );
    // Cluster rollup + per-node slices in one registry snapshot — the
    // tree the shared human summary renders from.
    let mut reg = MetricsRegistry::new();
    report.aggregate.register(&mut reg, "serve");
    report.ledger.register(&mut reg, "ledger");
    let pricing = obs::TierPricing::default();
    let mut econ = obs::HarvestEconomics::default();
    for n in &report.per_node {
        let p = format!("node{}", n.node);
        n.metrics.register(&mut reg, &format!("{p}.serve"));
        n.kv_stats.register(&mut reg, &format!("{p}.kv"));
        let e = obs::harvest_economics(&n.kv_stats, &pricing);
        e.register(&mut reg, &format!("{p}.economics"));
        econ.tax_ns += e.tax_ns;
        econ.dividend_ns += e.dividend_ns;
        if let Some(t) = &n.tenant {
            t.broker.register(&mut reg, &format!("{p}.tenant.broker"));
        }
    }
    econ.register(&mut reg, "economics");
    if let Some(a) = &report.attribution {
        a.register(&mut reg, "attrib");
    }
    for i in 0..cluster.n_nodes() {
        if let Some(a) = cluster.node(i).admission_stats() {
            a.register(&mut reg, &format!("node{i}.admission"));
        }
        cluster.node(i).runtime().monitor().register(&mut reg, &format!("node{i}.harvest.tiers"));
    }
    print_serve_summary(&reg);
    println!("{}", reg.to_json().to_string());
    if let Some(path) = report_path {
        write_report_file(path, &reg, report.attribution.as_ref())?;
    }
    Ok(())
}

/// Render the human serve summary from the registry snapshot — the same
/// tree `serve` prints as JSON and `--report` exports. Both the
/// single-node and the cluster path feed this one renderer, so the two
/// summaries cannot drift: the printed numbers ARE the registry values.
fn print_serve_summary(reg: &MetricsRegistry) {
    use harvest::obs::Metric;
    let counter = |name: &str| match reg.get(name) {
        Some(Metric::Counter(v)) => *v,
        _ => 0,
    };
    let gauge = |name: &str| match reg.get(name) {
        Some(Metric::Gauge(v)) => *v,
        _ => 0.0,
    };
    let p99 = |name: &str| match reg.get(name) {
        Some(Metric::Hist(h)) => h.percentile(99.0),
        _ => 0,
    };
    println!(
        "  served {} requests / {} tokens in {} -> {:.0} tok/s",
        counter("serve.requests_finished"),
        counter("serve.tokens_generated"),
        fmt_ns(counter("serve.makespan_ns")),
        gauge("serve.throughput_tps")
    );
    println!(
        "  admission: shed {} ({:.1}%), deferred {}, goodput {:.0} tok/s, p99 ttft {}",
        counter("serve.requests_shed"),
        100.0 * gauge("serve.shed_rate"),
        counter("serve.deferred_admissions"),
        gauge("serve.goodput_tok_s"),
        fmt_ns(p99("serve.ttft_ns"))
    );
    if reg.get("kv.hit_rate").is_some() {
        let reloads = counter("kv.peer_reloads")
            + counter("kv.cxl_reloads")
            + counter("kv.host_reloads")
            + counter("kv.ssd_reloads");
        println!(
            "  kv: hit-rate {:.1}%, {} reloads (peer {}, host {}), {} recomputes",
            100.0 * gauge("kv.hit_rate"),
            reloads,
            counter("kv.peer_reloads"),
            counter("kv.host_reloads"),
            counter("kv.recomputes")
        );
    }
    println!(
        "  harvest economics: tax {} vs dividend {} (net {:+.2} ms)",
        fmt_ns(counter("economics.harvest_tax_ns")),
        fmt_ns(counter("economics.harvest_dividend_ns")),
        gauge("economics.harvest_net_ns") / 1e6
    );
    if reg.get("attrib.requests").is_some() {
        println!(
            "  attribution: {} ledgers, {} unattributed of {} measured ttft",
            counter("attrib.requests"),
            fmt_ns(counter("attrib.unattributed_ns")),
            fmt_ns(counter("attrib.ttft_measured_ns"))
        );
    }
    for i in 0.. {
        let p = format!("node{i}");
        if reg.get(&format!("{p}.serve.requests_finished")).is_none() {
            break;
        }
        let reloads = counter(&format!("{p}.kv.peer_reloads"))
            + counter(&format!("{p}.kv.cxl_reloads"))
            + counter(&format!("{p}.kv.host_reloads"))
            + counter(&format!("{p}.kv.ssd_reloads"));
        println!(
            "    node {i}: {} served, {:.0} tok/s, {} kv reloads, p99 ttft {}",
            counter(&format!("{p}.serve.requests_finished")),
            gauge(&format!("{p}.serve.throughput_tps")),
            reloads,
            fmt_ns(p99(&format!("{p}.serve.ttft_ns")))
        );
    }
}

/// Write the `serve --report` document: the full registry snapshot plus
/// (when attribution ran) the per-request attribution report `analyze`
/// consumes.
fn write_report_file(
    path: &str,
    reg: &MetricsRegistry,
    attribution: Option<&obs::AttributionReport>,
) -> Result<()> {
    let mut doc = vec![("registry", reg.to_json())];
    if let Some(a) = attribution {
        doc.push(("attribution", a.to_json(8)));
    }
    std::fs::write(path, harvest::util::json::obj(doc).to_string() + "\n")
        .with_context(|| format!("writing report to {path}"))?;
    println!("  report: -> {path}");
    Ok(())
}

fn serve_real(cfg: &DeploymentConfig) -> Result<()> {
    let dir = std::env::var("HARVEST_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = ModelRuntime::load(Path::new(&dir))
        .with_context(|| format!("loading AOT artifacts from `{dir}` (run `make artifacts`)"))?;
    println!(
        "  model: tiny-moe d={} ({} weights, {} KV state) on {}",
        rt.config().d_model,
        fmt_bytes(rt.weights_bytes() as u64),
        fmt_bytes(rt.kv_state_bytes() as u64),
        "pjrt-cpu"
    );
    let mut engine = RealEngine::new(rt)?;
    let mut spec = cfg.workload_spec();
    // keep prompts inside the tiny model's context window
    spec.mean_prompt_tokens = spec.mean_prompt_tokens.min(48.0);
    spec.prompt_sigma = 0.3;
    let requests = WorkloadGen::new(spec).generate();
    let report = engine.serve(requests)?;
    let m = &report.metrics;
    println!(
        "  served {} requests / {} tokens in {:.2}s wall -> {:.1} tok/s, {} decode steps",
        m.requests_finished,
        m.tokens_generated,
        report.wall_seconds,
        m.tokens_generated as f64 / report.wall_seconds,
        report.decode_steps
    );
    Ok(())
}

// ---------------------------------------------------------------------
// presets / models / trace / transfer
// ---------------------------------------------------------------------

fn cmd_presets(args: &[String]) -> Result<()> {
    if let Some(name) = take_opt(args, "--dump") {
        let p = find_preset(&name).ok_or_else(|| anyhow!("unknown preset `{name}`"))?;
        print!("{}", p.to_toml());
        return Ok(());
    }
    println!("{:<16} {:<8} {:<6} {}", "NAME", "KIND", "GPUS", "NOTES");
    for p in presets() {
        let notes = match p.workload {
            WorkloadKind::MoeOffload => {
                format!("{} @ {:.0}% offload", p.moe_model, p.offload_fraction * 100.0)
            }
            WorkloadKind::KvOffload => {
                format!("{} / {} sched", p.kv_model, p.scheduler)
            }
            WorkloadKind::RealServe => "AOT tiny model, PJRT CPU".to_string(),
        };
        println!("{:<16} {:<8} {:<6} {}", p.name, p.workload.name(), p.n_gpus, notes);
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    println!("Table 1 — MoE architectures:");
    println!(
        "{:<14} {:>9} {:>10} {:>8} {:>6} {:>12}",
        "MODEL", "PARAMS(B)", "ACTIVE(B)", "EXPERTS", "TOP-K", "EXPERT SIZE"
    );
    for m in MOE_MODELS {
        println!(
            "{:<14} {:>9.1} {:>10.1} {:>8} {:>6} {:>12}",
            m.name,
            m.total_params_b,
            m.active_params_b,
            m.n_experts,
            m.top_k,
            fmt_bytes(m.expert_bytes())
        );
    }
    println!("\n§5.3 — KV-offload models (FP16):");
    println!("{:<22} {:>8} {:>16}", "MODEL", "LAYERS", "KV BYTES/TOKEN");
    for m in KV_MODELS {
        println!("{:<22} {:>8} {:>16}", m.name, m.n_layers, fmt_bytes(m.kv_bytes_per_token()));
    }
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let machines: usize =
        take_opt(args, "--machines").map(|s| s.parse()).transpose()?.unwrap_or(1800);
    let per: usize = take_opt(args, "--snapshots-per-machine")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64);
    let spec = TraceSpec { machines, snapshots_per_machine: per, ..Default::default() };
    let trace = ClusterTrace::synthesize(spec);
    println!(
        "synthesized {} snapshots over {} machines (gpu-v2020-like)",
        trace.len(),
        machines
    );
    println!("{:>12} {:>24}", "UTIL <=", "FRACTION OF MACHINES");
    for u in [0.1, 0.2, 0.3, 0.5, 0.7, 0.9] {
        println!("{:>11.0}% {:>23.1}%", u * 100.0, trace.cdf_at(u) * 100.0);
    }
    println!("mean utilisation: {:.1}%", trace.mean_util() * 100.0);
    println!("(paper Fig. 2: ~68% of machines <= 20% util, ~87% <= 50%)");
    Ok(())
}

fn cmd_transfer(args: &[String]) -> Result<()> {
    let chunks: Vec<f64> = {
        let given = take_all(args, "--chunk-mib");
        if given.is_empty() {
            vec![1.0, 4.0, 16.0, 64.0, 176.0, 352.0]
        } else {
            given.iter().map(|s| s.parse().map_err(|e| anyhow!("bad --chunk-mib: {e}"))).collect::<Result<_>>()?
        }
    };
    println!("{:>10} {:>14} {:>14} {:>9}", "CHUNK", "GPU<->GPU", "CPU<->GPU", "SPEEDUP");
    for mib in chunks {
        let bytes = (mib * (1 << 20) as f64) as u64;
        let mut node = SimNode::new(Default::default());
        let p2p = node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), bytes, None);
        let p2p_ns = p2p.end - p2p.start;
        let mut node = SimNode::new(Default::default());
        let h2d = node.copy(DeviceId::Host, DeviceId::Gpu(0), bytes, None);
        let h2d_ns = h2d.end - h2d.start;
        println!(
            "{:>10} {:>14} {:>14} {:>8.1}x",
            fmt_bytes(bytes),
            fmt_ns(p2p_ns),
            fmt_ns(h2d_ns),
            h2d_ns as f64 / p2p_ns as f64
        );
    }
    println!("(paper Fig. 3: speedups 7.5x Phi-tiny -> 9.5x Mixtral)");
    Ok(())
}

// ---------------------------------------------------------------------
// analyze / guard
// ---------------------------------------------------------------------

fn read_json(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    Json::parse(&text).with_context(|| format!("parsing {path} as JSON"))
}

/// Offline latency forensics: flamegraph-style rollups + top-K slow
/// spans out of a `serve --trace` document, and (with `--report`) the
/// causal attribution table + slowest-request breakdowns out of a
/// `serve --report` document. Pure reading — see [`harvest::obs::analyze`].
fn cmd_analyze(args: &[String]) -> Result<()> {
    let trace_path = take_opt(args, "--trace")
        .ok_or_else(|| anyhow!("analyze requires --trace FILE (from `serve --trace`)"))?;
    let top_k: usize = take_opt(args, "--top").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let a = obs::analyze::analyze_trace(&read_json(&trace_path)?, top_k)?;
    println!("trace {trace_path}: {} node(s), step time {}", a.nodes.len(), us(a.step_total_us));
    println!(
        "{:<12} {:<16} {:>8} {:>12} {:>12} {:>12} {:>7}",
        "SUBSYSTEM", "SPAN", "COUNT", "TOTAL", "MEAN", "MAX", "% STEP"
    );
    for sp in &a.spans {
        let pct = if a.step_total_us > 0.0 { 100.0 * sp.total_us / a.step_total_us } else { 0.0 };
        println!(
            "{:<12} {:<16} {:>8} {:>12} {:>12} {:>12} {:>6.1}%",
            sp.subsystem,
            sp.name,
            sp.count,
            us(sp.total_us),
            us(sp.mean_us()),
            us(sp.max_us),
            pct
        );
    }
    for (sub, name, count) in &a.instants {
        println!("{sub:<12} {name:<16} {count:>8}   (instants)");
    }
    if !a.slowest.is_empty() {
        println!("\ntop {} longest spans:", a.slowest.len());
        for sp in &a.slowest {
            println!(
                "  node {} {}/{} at {} for {}",
                sp.node,
                sp.subsystem,
                sp.name,
                us(sp.ts_us),
                us(sp.dur_us)
            );
        }
    }
    if let Some(rpath) = take_opt(args, "--report") {
        print_attribution_forensics(&read_json(&rpath)?)?;
    }
    Ok(())
}

/// Render the attribution sections of a `serve --report` document.
fn print_attribution_forensics(doc: &Json) -> Result<()> {
    let Some(rows) = obs::analyze::attribution_totals(doc) else {
        bail!("report has no `attribution` section (rerun serve with --report)");
    };
    let attrib = doc.get("attribution")?;
    let measured = attrib.get("e2e_measured_ns")?.as_u64()?;
    let unattributed = attrib.get("unattributed_ns")?.as_u64()?;
    println!("\ncausal attribution ({} requests):", attrib.get("requests")?.as_u64()?);
    println!("{:<18} {:>14} {:>14}", "COMPONENT", "TTFT", "DECODE");
    for (name, ttft, decode) in &rows {
        if *ttft == 0 && *decode == 0 {
            continue;
        }
        println!("{name:<18} {:>14} {:>14}", fmt_ns(*ttft), fmt_ns(*decode));
    }
    let attributed = measured.saturating_sub(unattributed);
    let cover = if measured > 0 { 100.0 * attributed as f64 / measured as f64 } else { 100.0 };
    println!("coverage: {cover:.2}% of measured latency ({} unattributed)", fmt_ns(unattributed));
    if let Some(slow) = obs::analyze::slow_requests(doc) {
        println!("\nslowest requests by TTFT:");
        for (id, ttft, e2e, comps) in &slow {
            let parts: Vec<String> = comps
                .iter()
                .filter(|(_, ns)| *ns > 0)
                .map(|(name, ns)| format!("{name} {}", fmt_ns(*ns)))
                .collect();
            println!(
                "  req {id}: ttft {} (e2e {}) <- {}",
                fmt_ns(*ttft),
                fmt_ns(*e2e),
                parts.join(", ")
            );
        }
    }
    Ok(())
}

/// Microseconds (trace units) -> human string via [`fmt_ns`].
fn us(us: f64) -> String {
    fmt_ns((us * 1e3) as u64)
}

/// Perf-trajectory regression gate (CI's `trajectory-guard` step): for
/// each guarded metric, compare the newest trajectory point against the
/// most recent earlier point from the same tier (smoke vs full) and fail
/// past `--threshold` (default 20%). Fewer than two comparable points
/// records a baseline and passes.
fn cmd_guard(args: &[String]) -> Result<()> {
    use harvest::util::bench::{latest_pair, load_trajectory, regression_frac};
    let threshold: f64 =
        take_opt(args, "--threshold").map(|s| s.parse()).transpose()?.unwrap_or(0.20);
    let dir = take_opt(args, "--dir").unwrap_or_else(|| ".".into());
    // (file, dotted metric, higher-is-better, display name)
    let checks = [
        (
            "BENCH_hot_path.json",
            "cluster steps/sec (16 nodes).steps_per_sec",
            true,
            "cluster steps/sec",
        ),
        (
            "BENCH_find_knee.json",
            "knee.occupancy_p99_pre_knee_ns",
            false,
            "p99 TTFT pre-knee (occupancy admission)",
        ),
    ];
    let mut regressed = Vec::new();
    for (file, metric, higher_better, label) in checks {
        let points = load_trajectory(&Path::new(&dir).join(file));
        match latest_pair(&points, metric) {
            None => println!(
                "guard: {label}: baseline recorded ({} point(s) in {file}, need 2 comparable)",
                points.len()
            ),
            Some((prev, latest)) => {
                let frac = regression_frac(prev, latest, higher_better);
                let verdict = if frac > threshold { "REGRESSED" } else { "ok" };
                println!(
                    "guard: {label}: {prev:.1} -> {latest:.1} ({:+.1}% vs previous) [{verdict}]",
                    100.0 * frac
                );
                if frac > threshold {
                    regressed.push(label);
                }
            }
        }
    }
    if !regressed.is_empty() {
        bail!(
            "perf trajectory regressed past {:.0}%: {}",
            100.0 * threshold,
            regressed.join(", ")
        );
    }
    Ok(())
}
