//! Tier ladder — the cold-tier aging ladder end to end, in virtual time.
//!
//! Idle sessions spill out of the local KV pool to peer HBM, then an
//! aging daemon (`KvOffloadManager::age_idle_blocks`, one rung per
//! sweep) walks them down the ladder: peer HBM → host DRAM →
//! compressed-in-place → the paged SSD arena. The sweep here varies the
//! idle age (number of 5 ms aging periods a session has sat cold) and
//! reports where the bytes live afterwards, plus the full comeback cost
//! when decode touches the sequences again — which must complete with
//! **zero recomputes** at every rung: the ladder trades latency for
//! recomputation, never correctness.
//!
//! A second section replays the pressure path from the integration
//! suite: a guaranteed-priority tenant burst displaces every harvest
//! lease, and the `compress_before_demote` ladder (compress → demote →
//! drop) is compared against the bare revocation path. With the ladder
//! the burst costs compressions and demotions; without it the same
//! burst costs recomputes.
//!
//! A machine-readable summary is written to `BENCH_tier_ladder.json`
//! (see `util::bench::JsonReport`).
//!
//! Run: `cargo bench --bench tier_ladder` (`-- --smoke` for the CI
//! short run).

use harvest::harvest::{HarvestConfig, HarvestRuntime, MemoryTier};
use harvest::kv::{KvConfig, KvOffloadManager, KvStats, SeqId};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::obs::MetricsRegistry;
use harvest::server::{AgingConfig, Fcfs, SimEngine, SimEngineConfig, WorkloadGen, WorkloadSpec};
use harvest::tenantsim::{BatchActor, TenantFleet, TenantPriority};
use harvest::util::bench::{JsonReport, Table};
use harvest::util::json::{obj, Json};
use harvest::util::{fmt_bytes, fmt_ns};

const GIB: u64 = 1 << 30;
/// Aging daemon period: each sweep steps idle blocks one rung down.
const SWEEP_NS: u64 = 5_000_000;
/// In-place compression target on the compress rung.
const RATIO_PCT: u32 = 50;
const BLOCKS_PER_SEQ: u64 = 12;

/// Fresh runtime + KV manager with `seqs` sequences appended through a
/// 4-block local pool, so nearly everything spills to peer HBM (lossy:
/// only the ladder keeps the spill alive under pressure).
fn build(seqs: u64, ladder: bool) -> (HarvestRuntime, KvOffloadManager) {
    let mut hcfg = HarvestConfig::for_node(2);
    if ladder {
        hcfg.demote_to_host = true;
        hcfg.compress_before_demote = true;
    }
    let spec = if ladder {
        NodeSpec::h100x2().with_ssd(256 * GIB)
    } else {
        NodeSpec::h100x2()
    };
    let mut hr = HarvestRuntime::new(SimNode::new(spec), hcfg);
    let kv_cfg = KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 4,
        use_harvest: true,
        host_backed_peer: false,
    };
    let mut kv = KvOffloadManager::new(kv_cfg, 0);
    for s in 0..seqs {
        for _ in 0..16 * BLOCKS_PER_SEQ {
            kv.append_token(&mut hr, SeqId(s));
        }
    }
    assert!(kv.stats.evictions_to_peer > 0, "tight pool must spill to peer");
    (hr, kv)
}

struct LadderRow {
    idle_ns: u64,
    stepped: usize,
    peer: u64,
    host: u64,
    ssd: u64,
    compressed: usize,
    comeback_ns: u64,
    decompress_ns: u64,
    ssd_reloads: u64,
}

/// Age the spilled sessions for `sweeps` periods, then bring them all
/// back through decode and account the round trip.
fn ladder_row(seqs: u64, sweeps: u32) -> LadderRow {
    let (mut hr, mut kv) = build(seqs, true);
    let mut stepped = 0;
    for _ in 0..sweeps {
        let now = hr.node.clock.now();
        hr.advance_to(now + SWEEP_NS);
        stepped += kv.age_idle_blocks(&mut hr, SWEEP_NS, RATIO_PCT);
    }
    kv.sync(&mut hr);
    let peer = hr.live_bytes_on_tier(MemoryTier::PeerHbm(1));
    let host = hr.live_bytes_on_tier(MemoryTier::Host);
    let ssd = hr.live_bytes_on_tier(MemoryTier::Ssd);
    let compressed = kv.compressed_blocks().count();
    let start = hr.node.clock.now();
    for s in 0..seqs {
        kv.access_seq(&mut hr, SeqId(s));
    }
    let comeback_ns = hr.node.clock.now() - start;
    assert_eq!(
        kv.stats.recomputes, 0,
        "the ladder must bring every block home without recompute (sweeps {sweeps})"
    );
    kv.check_invariants().unwrap();
    LadderRow {
        idle_ns: u64::from(sweeps) * SWEEP_NS,
        stepped,
        peer,
        host,
        ssd,
        compressed,
        comeback_ns,
        decompress_ns: kv.stats.decompress_ns,
        ssd_reloads: kv.stats.ssd_reloads,
    }
}

struct PressureRow {
    compressions: u64,
    demotions: u64,
    recomputes: u64,
    revocations: u64,
}

/// The integration-suite pressure path: a guaranteed batch tenant
/// bursts to the whole peer GPU, displacing every harvest lease, then
/// decode touches the sequences again.
fn pressure_row(seqs: u64, ladder: bool) -> PressureRow {
    let (mut hr, mut kv) = build(seqs, ladder);
    let mut fleet = TenantFleet::new();
    fleet.push(Box::new(BatchActor::new(
        "batch-0",
        1,
        80 * GIB,
        2_000_000,
        2_000_000,
        TenantPriority::Guaranteed,
        3,
    )));
    for t in 1..=5u64 {
        let now = hr.node.clock.now();
        fleet.advance_to(&mut hr, now.max(t * 2_000_000));
    }
    kv.sync(&mut hr);
    for s in 0..seqs {
        kv.access_seq(&mut hr, SeqId(s));
    }
    kv.check_invariants().unwrap();
    PressureRow {
        compressions: kv.stats.compressions,
        demotions: kv.stats.demotions,
        recomputes: kv.stats.recomputes,
        revocations: hr.revocations.len() as u64,
    }
}

/// The ladder driven at the serving loop's own cadence: an engine run
/// with [`AgingConfig`] wired into [`SimEngineConfig`], staggered
/// shared-prefix arrivals leaving the cached prefix idle between
/// requests. Previously `age_idle_blocks` was driven by *neither*
/// serving loop — only this bench called it by hand; now the stepper
/// sweeps it on the configured period for the engine and every cluster
/// node alike.
fn engine_cadence_row(smoke: bool) -> (u64, KvStats) {
    let mut hcfg = HarvestConfig::for_node(2);
    hcfg.demote_to_host = true;
    hcfg.compress_before_demote = true;
    let mut hr =
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2().with_ssd(256 * GIB)), hcfg);
    let kv_cfg = KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 8,
        use_harvest: true,
        host_backed_peer: false,
    };
    let engine = SimEngineConfig::new(kv_cfg, 2, 4).with_aging(AgingConfig {
        sweep_ns: SWEEP_NS,
        idle_ns: SWEEP_NS,
        ratio_pct: RATIO_PCT,
    });
    let mut eng = SimEngine::new(engine, Box::new(Fcfs::new()), 0);
    let reqs = WorkloadGen::new(WorkloadSpec {
        n_requests: if smoke { 6 } else { 12 },
        mean_prompt_tokens: 96.0,
        max_new_tokens: 6,
        mean_interarrival_ns: 4 * SWEEP_NS,
        shared_prefix_fraction: 0.8,
        shared_prefix_tokens: 32,
        n_prefix_groups: 1,
        seed: 3,
        ..Default::default()
    })
    .generate();
    let report = eng.run(&mut hr, reqs);
    assert_eq!(
        report.kv_stats.recomputes, 0,
        "cadence-driven aging must never cost recomputes"
    );
    (report.steps, report.kv_stats)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let seqs = if smoke { 2 } else { 4 };
    let mut json = JsonReport::new("BENCH_tier_ladder.json");

    println!(
        "tier ladder — idle-age sweep over the cold-tier aging ladder\n\
         ({seqs} sequences x {BLOCKS_PER_SEQ} blocks, 4-block local pool, one rung per {} sweep)\n",
        fmt_ns(SWEEP_NS)
    );
    let t = Table::new(&[8, 6, 10, 10, 10, 6, 11, 11]);
    t.row(&[
        "IDLE".into(),
        "STEPS".into(),
        "PEER".into(),
        "HOST".into(),
        "SSD".into(),
        "CBLKS".into(),
        "COMEBACK".into(),
        "DECOMP".into(),
    ]);
    t.sep();
    for sweeps in 0..=3u32 {
        let r = ladder_row(seqs, sweeps);
        match sweeps {
            1 => assert!(r.host > 0, "first sweep must land spill on host DRAM"),
            2 => assert!(r.compressed > 0, "second sweep must compress in place"),
            3 => {
                assert!(r.ssd > 0, "third sweep must page out to the SSD arena");
                assert!(r.ssd_reloads > 0, "comeback must reload from SSD");
                assert!(r.decompress_ns > 0, "SSD comeback pays decompression");
            }
            _ => {}
        }
        t.row(&[
            fmt_ns(r.idle_ns),
            format!("{}", r.stepped),
            fmt_bytes(r.peer),
            fmt_bytes(r.host),
            fmt_bytes(r.ssd),
            format!("{}", r.compressed),
            fmt_ns(r.comeback_ns),
            fmt_ns(r.decompress_ns),
        ]);
        json.add(
            &format!("idle_{}ms", u64::from(sweeps) * SWEEP_NS / 1_000_000),
            obj([
                ("idle_ns", Json::from(r.idle_ns)),
                ("rung_steps", Json::from(r.stepped)),
                ("peer_bytes", Json::from(r.peer)),
                ("host_bytes", Json::from(r.host)),
                ("ssd_bytes", Json::from(r.ssd)),
                ("compressed_blocks", Json::from(r.compressed)),
                ("comeback_ns", Json::from(r.comeback_ns)),
                ("decompress_ns", Json::from(r.decompress_ns)),
                ("ssd_reloads", Json::from(r.ssd_reloads)),
                ("recomputes", Json::from(0u64)),
            ]),
        );
    }

    println!("\npressure burst (guaranteed tenant displaces every lease):\n");
    let p = Table::new(&[12, 10, 9, 11, 10]);
    p.row(&[
        "LADDER".into(),
        "COMPRESS".into(),
        "DEMOTE".into(),
        "RECOMPUTE".into(),
        "REVOKE".into(),
    ]);
    p.sep();
    for ladder in [true, false] {
        let r = pressure_row(seqs, ladder);
        if ladder {
            assert_eq!(r.recomputes, 0, "ladder on: the burst must cost zero recomputes");
            assert!(r.compressions > 0, "ladder on: pressure compresses before demoting");
        } else {
            assert!(r.recomputes > 0, "ladder off: displaced lossy blocks recompute");
        }
        p.row(&[
            if ladder { "on" } else { "off" }.into(),
            format!("{}", r.compressions),
            format!("{}", r.demotions),
            format!("{}", r.recomputes),
            format!("{}", r.revocations),
        ]);
        json.add(
            if ladder { "pressure_ladder_on" } else { "pressure_ladder_off" },
            obj([
                ("compressions", Json::from(r.compressions)),
                ("demotions", Json::from(r.demotions)),
                ("recomputes", Json::from(r.recomputes)),
                ("revocations", Json::from(r.revocations)),
            ]),
        );
    }

    println!("\nengine-cadence aging (stepper-driven sweeps, staggered prefix reuse):\n");
    let (steps, stats) = engine_cadence_row(smoke);
    println!(
        "  {} steps, {} demotions, {} compressions, {} ssd reloads, 0 recomputes",
        steps, stats.demotions, stats.compressions, stats.ssd_reloads
    );
    // The full KvStats registry subtree for the cadence run — same
    // names `serve` prints under `kv.*`, so the ladder's reload/demote
    // economics line up with serve output key-for-key.
    let mut reg = MetricsRegistry::new();
    stats.register(&mut reg, "kv");
    json.add(
        "engine_cadence",
        obj([
            ("steps", Json::from(steps)),
            ("demotions", Json::from(stats.demotions)),
            ("compressions", Json::from(stats.compressions)),
            ("ssd_reloads", Json::from(stats.ssd_reloads)),
            ("recomputes", Json::from(stats.recomputes)),
            ("reloads", Json::from(stats.reloads())),
            ("registry", reg.to_json()),
        ]),
    );

    let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
    match json.append_trajectory(&label, smoke) {
        Ok(()) => println!("\nappended point `{label}` to {}", json.path().display()),
        Err(e) => println!("\ncould not write {}: {e}", json.path().display()),
    }
    println!(
        "\ntakeaway: idle sessions descend peer -> host -> compressed -> SSD and every\n\
         rung still pages back in with zero recomputes — deeper rungs trade comeback\n\
         latency (NVMe + decompression) for freed hot-tier capacity, and under a\n\
         pressure burst the same ladder turns forced drops into compress/demote."
    );
}
