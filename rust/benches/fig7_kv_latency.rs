//! Fig. 7 — KV-cache transfer latency for CPU vs peer-GPU reloads, for
//! the §5.3 models (DeepSeek-V3, Mistral-Large-3-675B, Kimi-K2) at FP16
//! across chunk sizes of 100–8000 KV cache entries.
//!
//! Tier-aware edition: each reload is a lease pinned to the tier under
//! test, fetched through the chunked `Transfer` path the
//! `KvOffloadManager` uses (scattered ~4 MiB DMA descriptors).
//!
//! Paper anchors: Kimi-K2 speedup 5.42× (100 entries) → 5.68× (8000);
//! Mistral-Large-3 ~3× → 5.65× over the same range.
//!
//! Run: `cargo bench --bench fig7_kv_latency`

use harvest::harvest::{
    AllocHints, HarvestConfig, HarvestRuntime, MemoryTier, PayloadKind, TierPreference, Transfer,
};
use harvest::kv::manager::RELOAD_CHUNK_BYTES;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::KV_MODELS;
use harvest::util::bench::Table;
use harvest::util::{fmt_bytes, fmt_ns};

const ENTRIES: &[u64] = &[100, 500, 1000, 2000, 4000, 8000];

/// One reload measurement: a lease on `tier`, fetched to GPU 0 as
/// scattered block copies batched into ~4 MiB DMA descriptors — the same
/// path `KvOffloadManager::ensure_local` pays.
fn reload(tier: MemoryTier, bytes: u64) -> u64 {
    let mut hr =
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let session = hr.open_session(PayloadKind::KvBlock);
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
    let lease = session
        .alloc(&mut hr, bytes, TierPreference::Pinned(tier), hints)
        .expect("fresh node has capacity");
    let report = Transfer::new()
        .chunked(RELOAD_CHUNK_BYTES)
        .fetch(&lease, 0)
        .submit(&mut hr)
        .expect("live lease");
    let ns = report.events[0].duration();
    session.release(&mut hr, lease).expect("live lease");
    ns
}

fn main() {
    println!("Fig. 7 — KV cache transfer latency, CPU vs peer-GPU reloads (FP16)\n");
    for m in KV_MODELS {
        println!("{} ({} KiB per KV entry):", m.name, m.kv_bytes_per_token() / 1024);
        let table = Table::new(&[10, 12, 13, 13, 9, 9]);
        table.row(&[
            "ENTRIES".into(),
            "BYTES".into(),
            "GPU RELOAD".into(),
            "CPU RELOAD".into(),
            "SPEEDUP".into(),
            "PAPER".into(),
        ]);
        table.sep();
        for &n in ENTRIES {
            let bytes = n * m.kv_bytes_per_token();
            let p2p = reload(MemoryTier::PeerHbm(1), bytes);
            let h2d = reload(MemoryTier::Host, bytes);
            let paper = match (m.name, n) {
                ("Kimi-K2", 100) => "5.42x",
                ("Kimi-K2", 8000) => "5.68x",
                ("Mistral-Large-3-675B", 100) => "~3x",
                ("Mistral-Large-3-675B", 8000) => "5.65x",
                _ => "-",
            };
            table.row(&[
                format!("{n}"),
                fmt_bytes(bytes),
                fmt_ns(p2p),
                fmt_ns(h2d),
                format!("{:.2}x", h2d as f64 / p2p as f64),
                paper.into(),
            ]);
        }
        println!();
    }
    println!(
        "(reloads batched into {} DMA descriptors — the KvOffloadManager lease path)",
        fmt_bytes(RELOAD_CHUNK_BYTES)
    );
}
