//! Hot-path wall-clock microbenchmarks — the criterion-style suite the
//! §Perf optimization pass iterates on. Measures the L3 coordinator
//! primitives (allocation, placement, fetch, KV access, scheduling,
//! whole decode passes) and, when `artifacts/` is present, the real PJRT
//! decode step (the L1/L2 hot path as seen from Rust).
//!
//! Each measurement is also recorded into `BENCH_hot_path.json` (see
//! `util::bench::JsonReport`) so CI can diff runs without scraping the
//! aligned-table stdout.
//!
//! Run: `cargo bench --bench hot_path`

use harvest::cluster::{Cluster, ClusterSpec, Event, EventCalendar, RouterPolicy, SchedulerSpec};
use harvest::harvest::{AllocHints, HarvestConfig, HarvestRuntime, PayloadKind, TierPreference};
use harvest::kv::{KvConfig, KvOffloadManager, SeqId};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{find_kv_model, find_moe_model, CgoPipe, ExpertRebalancer, RouterSim};
use harvest::obs::profile::{self, Phase};
use harvest::obs::trace as obstrace;
use harvest::runtime::{DecodeSlot, ModelRuntime};
use harvest::server::{CompletelyFair, Scheduler, SimEngineConfig, WorkloadGen, WorkloadSpec};
use harvest::trace::{ClusterTrace, TraceSpec};
use harvest::util::bench::{sink, Bench, JsonReport, WallResult};
use harvest::util::json::{obj, Json};
use std::path::Path;
use std::time::Instant;

const MIB: u64 = 1 << 20;

/// Record one wall measurement into the machine-readable summary.
fn rec(json: &mut JsonReport, r: WallResult) {
    json.add(
        &r.name,
        obj([
            ("mean_ns", Json::from(r.mean_ns)),
            ("p50_ns", Json::from(r.p50_ns)),
            ("p99_ns", Json::from(r.p99_ns)),
            ("iters", Json::from(u64::from(r.iters))),
        ]),
    );
}

// Measures the deprecated raw shim deliberately: it is the §3.2 paper
// surface and stays until the lease migration completes.
#[allow(deprecated)]
fn bench_harvest_alloc_free(b: &Bench, json: &mut JsonReport) {
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
    rec(
        json,
        b.wall("harvest_alloc+free (64 MiB, 2-GPU)", || {
            let h = hr.alloc(64 * MIB, hints).unwrap();
            hr.free(h.id).unwrap();
        }),
    );
    // Placement cost grows with domain size: policy scans all peers.
    let mut hr8 =
        HarvestRuntime::new(SimNode::new(NodeSpec::nvlink_domain(8)), HarvestConfig::for_node(8));
    rec(
        json,
        b.wall("harvest_alloc+free (64 MiB, 8-GPU)", || {
            let h = hr8.alloc(64 * MIB, hints).unwrap();
            hr8.free(h.id).unwrap();
        }),
    );
}

#[allow(deprecated)] // raw-shim fragmentation path, same rationale as above
fn bench_alloc_under_fragmentation(b: &Bench, json: &mut JsonReport) {
    // 2000 standing allocations fragment the arena; measure steady-state
    // alloc/free with a full policy view rebuild.
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
    let standing: Vec<_> =
        (0..2000).map(|i| hr.alloc((1 + i % 16) * MIB, hints).unwrap()).collect();
    sink(&standing);
    rec(
        json,
        b.wall("harvest_alloc+free (2000 standing allocs)", || {
            let h = hr.alloc(8 * MIB, hints).unwrap();
            hr.free(h.id).unwrap();
        }),
    );
}

fn bench_lease_session_paths(b: &Bench, json: &mut JsonReport) {
    // The redesigned surface: RAII tier-aware lease alloc/release, and
    // the vectored alloc_many path (one policy consultation per 16-block
    // batch vs 16).
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let session = hr.open_session(PayloadKind::KvBlock);
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
    rec(
        json,
        b.wall("session alloc+release (64 MiB lease)", || {
            let lease =
                session.alloc(&mut hr, 64 * MIB, TierPreference::FastestAvailable, hints).unwrap();
            session.release(&mut hr, lease).unwrap();
        }),
    );
    let sizes = [4 * MIB; 16];
    rec(
        json,
        b.wall("session alloc_many+release (16 x 4 MiB)", || {
            let batch = session
                .alloc_many(&mut hr, &sizes, TierPreference::FastestAvailable, hints)
                .unwrap();
            for lease in batch {
                session.release(&mut hr, lease).unwrap();
            }
        }),
    );
    rec(
        json,
        b.wall("scalar alloc x16 +release (4 MiB each)", || {
            let batch: Vec<_> = (0..16)
                .map(|_| {
                    session
                        .alloc(&mut hr, 4 * MIB, TierPreference::FastestAvailable, hints)
                        .unwrap()
                })
                .collect();
            for lease in batch {
                session.release(&mut hr, lease).unwrap();
            }
        }),
    );
    // Cross-tier placement: the policy scores peer vs host vs CXL per
    // alloc — the tier decision is on the allocation hot path now.
    let mut hr_cxl = HarvestRuntime::new(
        SimNode::new(NodeSpec::h100x2().with_cxl(256 * (1 << 30))),
        HarvestConfig::for_node(2),
    );
    let s2 = hr_cxl.open_session(PayloadKind::KvBlock);
    rec(
        json,
        b.wall("session alloc+release (3-tier node)", || {
            let lease = s2
                .alloc(&mut hr_cxl, 64 * MIB, TierPreference::FastestAvailable, hints)
                .unwrap();
            s2.release(&mut hr_cxl, lease).unwrap();
        }),
    );
    rec(
        json,
        b.wall("lease migrate peer->host->peer (64 MiB)", || {
            let lease = s2.alloc(&mut hr_cxl, 64 * MIB, TierPreference::PEER_ONLY, hints).unwrap();
            harvest::harvest::Transfer::new()
                .migrate(&lease, harvest::harvest::MemoryTier::Host)
                .submit(&mut hr_cxl)
                .unwrap();
            harvest::harvest::Transfer::new()
                .migrate(&lease, harvest::harvest::MemoryTier::PeerHbm(1))
                .submit(&mut hr_cxl)
                .unwrap();
            s2.release(&mut hr_cxl, lease).unwrap();
        }),
    );
}

fn bench_expert_fetch(b: &Bench, json: &mut JsonReport) {
    let model = find_moe_model("mixtral").unwrap();
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let mut reb = ExpertRebalancer::new(model, 0, 0.5);
    reb.rebalance(&mut hr, usize::MAX);
    let peer_key = harvest::moe::ExpertKey { layer: 0, expert: reb.model.n_experts as u32 / 2 };
    rec(
        json,
        b.wall("fetch_expert (peer hit, Mixtral)", || {
            sink(reb.fetch_expert(&mut hr, peer_key));
        }),
    );
    let host_key = harvest::moe::ExpertKey { layer: 0, expert: 0 };
    rec(
        json,
        b.wall("fetch_expert (local hit, Mixtral)", || {
            sink(reb.fetch_expert(&mut hr, host_key));
        }),
    );
}

fn bench_kv_ops(b: &Bench, json: &mut JsonReport) {
    let cfg = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 4096,
        use_harvest: true,
        host_backed_peer: false,
    };
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let mut kv = KvOffloadManager::new(cfg, 0);
    rec(
        json,
        b.wall("kv append_token (no eviction)", || {
            sink(kv.append_token(&mut hr, SeqId(1)));
        }),
    );
    // tight pool: every append evicts (the churn path §6.3 stresses)
    let tight = KvConfig { local_capacity_blocks: 8, ..cfg };
    let mut hr2 =
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let mut kv2 = KvOffloadManager::new(tight, 0);
    for _ in 0..32 * 16 {
        kv2.append_token(&mut hr2, SeqId(1));
    }
    rec(
        json,
        b.wall("kv append_token (evicting)", || {
            sink(kv2.append_token(&mut hr2, SeqId(1)));
        }),
    );
    rec(
        json,
        b.wall("kv access_seq (hot, 4096-block pool)", || {
            sink(kv.access_seq(&mut hr, SeqId(1)));
        }),
    );
}

fn bench_router_and_scheduler(b: &Bench, json: &mut JsonReport) {
    let model = find_moe_model("qwen").unwrap();
    let mut router = RouterSim::new(model, model.n_layers as usize, 1);
    rec(
        json,
        b.wall("route_microbatch (324 tok, Qwen 64-expert)", || {
            sink(router.route_microbatch(0, 324));
        }),
    );
    let mut cf = CompletelyFair::new(1);
    for i in 0..256 {
        cf.admit(SeqId(i));
    }
    rec(
        json,
        b.wall("CF select (256 runnable, 32 slots)", || {
            sink(cf.select(32));
        }),
    );
}

fn bench_decode_pass(b: &Bench, json: &mut JsonReport) {
    // Whole CGOPipe decode pass in virtual time — wall time here is the
    // simulator's own overhead (the L3 inner loop).
    let model = find_moe_model("qwen").unwrap();
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let pipe = CgoPipe::paper_setup(model);
    let mut router = RouterSim::new(model, model.n_layers as usize, 2);
    let mut reb = ExpertRebalancer::new(model, 0, 0.5);
    reb.rebalance(&mut hr, usize::MAX);
    rec(
        json,
        b.wall("CGOPipe decode_pass (Qwen, 4536 tok)", || {
            sink(pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest));
        }),
    );
}

fn bench_trace(b: &Bench, json: &mut JsonReport) {
    let spec = TraceSpec { machines: 200, snapshots_per_machine: 64, ..Default::default() };
    rec(
        json,
        b.wall("trace synthesize (12.8k snapshots)", || {
            sink(ClusterTrace::synthesize(spec.clone()));
        }),
    );
}

fn bench_dispatch(b: &Bench, json: &mut JsonReport) {
    // The cluster's dispatch decision, isolated: pick the next due event
    // across 64 busy nodes. Calendar = O(log heap) pop + lazy refresh
    // (what `Cluster::run` does now); linear scan = O(nodes) min over
    // every node per event (what it did before). 1024 dispatches per
    // sample amortize the timer.
    const N: usize = 64;
    let seed_times = |i: u64| i * 17 % 101;
    let mut cal = EventCalendar::new(N);
    for i in 0..N {
        cal.refresh_node(i, true, seed_times(i as u64));
    }
    let mut tick = 0u64;
    rec(
        json,
        b.wall("dispatch x1024 (64 nodes, calendar)", || {
            for _ in 0..1024 {
                if let Some((at, Event::NodeReady(n))) = cal.pop() {
                    tick += 1;
                    cal.refresh_node(n, true, at + 1 + tick % 7);
                }
            }
            sink(tick);
        }),
    );
    let mut times: Vec<u64> = (0..N as u64).map(seed_times).collect();
    let mut tick2 = 0u64;
    rec(
        json,
        b.wall("dispatch x1024 (64 nodes, linear scan)", || {
            for _ in 0..1024 {
                let mut best = u64::MAX;
                let mut who = 0usize;
                for (i, &t) in times.iter().enumerate() {
                    if t < best {
                        best = t;
                        who = i;
                    }
                }
                tick2 += 1;
                times[who] = best + 1 + tick2 % 7;
            }
            sink(tick2);
        }),
    );
}

fn cluster_steps_workload(smoke: bool) -> (ClusterSpec, KvConfig, Vec<harvest::server::Request>) {
    let kv = KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 48,
        use_harvest: true,
        host_backed_peer: false,
    };
    let mut spec = ClusterSpec::new(16);
    spec.router = RouterPolicy::LeastLoaded;
    let reqs = WorkloadGen::new(WorkloadSpec {
        n_requests: if smoke { 64 } else { 512 },
        mean_prompt_tokens: 64.0,
        max_new_tokens: 8,
        mean_interarrival_ns: 100_000,
        shared_prefix_fraction: 0.5,
        shared_prefix_tokens: 32,
        n_prefix_groups: 4,
        seed: 7,
        ..Default::default()
    })
    .generate();
    (spec, kv, reqs)
}

fn bench_cluster_steps(json: &mut JsonReport, smoke: bool) -> f64 {
    // End-to-end stepping throughput of the event-calendar cluster loop:
    // one 16-node run under memory pressure with staggered arrivals (the
    // dispatch-bound regime the laggard scan was worst at), reported as
    // stepper iterations per wall second.
    let (spec, kv, reqs) = cluster_steps_workload(smoke);
    let mut cluster = Cluster::new(&spec, SimEngineConfig::new(kv, 4, 8), SchedulerSpec::Fcfs);
    let t = Instant::now();
    let report = sink(cluster.run(reqs));
    let wall_ns = t.elapsed().as_nanos() as u64;
    let steps: u64 = report.per_node.iter().map(|n| n.steps).sum();
    let steps_per_sec = steps as f64 * 1e9 / wall_ns as f64;
    println!(
        "{:<44} {:>12.0} steps/s   ({} steps / {} reqs)",
        "cluster steps/sec (16 nodes)",
        steps_per_sec,
        steps,
        report.aggregate.requests_finished
    );
    json.add(
        "cluster steps/sec (16 nodes)",
        obj([
            ("steps", Json::from(steps)),
            ("wall_ns", Json::from(wall_ns)),
            ("steps_per_sec", Json::from(steps_per_sec)),
        ]),
    );
    steps_per_sec
}

fn bench_cluster_steps_profiled(json: &mut JsonReport, smoke: bool) {
    // Same workload with the per-phase stepper profiler on: where the
    // wall clock of a step actually goes (coverage = fraction of total
    // step time attributed to a named phase).
    let (spec, kv, reqs) = cluster_steps_workload(smoke);
    let mut cluster = Cluster::new(&spec, SimEngineConfig::new(kv, 4, 8), SchedulerSpec::Fcfs);
    profile::reset();
    profile::enable();
    sink(cluster.run(reqs));
    profile::disable();
    let prof = profile::snapshot();
    println!(
        "{:<44} {:>11.1}% phase coverage ({} steps profiled)",
        "stepper phase profile (16 nodes)",
        prof.coverage() * 100.0,
        prof.calls(Phase::Total)
    );
    json.add("stepper phase profile (16 nodes)", prof.to_json());
}

fn bench_obs_disabled_overhead(json: &mut JsonReport, steps_per_sec: f64) {
    // The zero-overhead-when-off contract, measured: a disabled phase
    // timer and a disabled trace instant must stay in the nanoseconds —
    // they sit on every step of the serving hot path. The hard bound
    // below fails the bench (and CI's smoke run) on a regression.
    profile::disable();
    obstrace::disable();
    const N: u64 = 1_000_000;
    let t = Instant::now();
    for _ in 0..N {
        let _ = sink(profile::timer(Phase::Compute));
    }
    let timer_ns = t.elapsed().as_nanos() as f64 / N as f64;
    let t = Instant::now();
    for i in 0..N {
        obstrace::instant(obstrace::Subsystem::Stepper, "tick", i, &[]);
    }
    let instant_ns = t.elapsed().as_nanos() as f64 / N as f64;
    println!(
        "{:<44} {:>9.1} ns timer, {:.1} ns instant (disabled)",
        "obs disabled-mode overhead", timer_ns, instant_ns
    );
    json.add(
        "obs disabled-mode overhead",
        obj([
            ("timer_ns_per_call", Json::from(timer_ns)),
            ("instant_ns_per_call", Json::from(instant_ns)),
            ("cluster_steps_per_sec", Json::from(steps_per_sec)),
        ]),
    );
    const BOUND_NS: f64 = 100.0;
    assert!(
        timer_ns < BOUND_NS && instant_ns < BOUND_NS,
        "disabled-mode observability overhead regressed: timer {timer_ns:.1} ns, \
         instant {instant_ns:.1} ns (bound {BOUND_NS} ns)"
    );
}

fn bench_pjrt_decode(json: &mut JsonReport) {
    let dir = std::env::var("HARVEST_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !Path::new(&dir).join("manifest.json").exists() {
        println!("(skipping PJRT decode bench: no {dir}/manifest.json — run `make artifacts`)");
        return;
    }
    let mut rt = match ModelRuntime::load(Path::new(&dir)) {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping PJRT decode bench: {e:#})");
            return;
        }
    };
    let cfg = rt.config().clone();
    for &bsz in &rt.batch_variants() {
        let slots: Vec<DecodeSlot> = (0..bsz)
            .map(|i| DecodeSlot {
                token: (i % cfg.vocab) as i32,
                pos: 0,
                page_table: (0..cfg.max_pages_per_seq).map(|p| p as i32).collect(),
            })
            .collect();
        let small = Bench::new(2, 10);
        rec(
            json,
            small.wall(&format!("PJRT decode step (batch {bsz})"), || {
                sink(rt.decode(&slots).expect("decode"));
            }),
        );
        rt.reset_kv().unwrap();
    }
}

fn main() {
    // `--smoke` (CI): only the cluster-dispatch arms, few iterations —
    // proves the calendar pair + 16-node end-to-end arm run and emit
    // their JSON without paying for the full suite.
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("== Harvest hot-path wall-clock benches ==\n");
    Bench::header();
    let b = if smoke { Bench::new(1, 5) } else { Bench::default() };
    let mut json = JsonReport::new("BENCH_hot_path.json");
    if !smoke {
        bench_harvest_alloc_free(&b, &mut json);
        bench_alloc_under_fragmentation(&b, &mut json);
        bench_lease_session_paths(&b, &mut json);
        bench_expert_fetch(&b, &mut json);
        bench_kv_ops(&b, &mut json);
        bench_router_and_scheduler(&b, &mut json);
        bench_decode_pass(&b, &mut json);
        bench_trace(&b, &mut json);
    }
    bench_dispatch(&b, &mut json);
    let steps_per_sec = bench_cluster_steps(&mut json, smoke);
    bench_cluster_steps_profiled(&mut json, smoke);
    bench_obs_disabled_overhead(&mut json, steps_per_sec);
    if !smoke {
        bench_pjrt_decode(&mut json);
    }
    let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
    match json.append_trajectory(&label, smoke) {
        Ok(()) => println!("\nappended point `{label}` to {}", json.path().display()),
        Err(e) => println!("\ncould not write {}: {e}", json.path().display()),
    }
}
