//! §6.3 — Completely Fair Decoding ablation: token-level preemption
//! amplifies KV working-set churn; peer-HBM offloading acts as a
//! *scheduler robustness mechanism* by lowering the marginal cost of
//! preemption-induced reloads.
//!
//! The bench crosses {FCFS, CF(q=4), CF(q=1)} × {host offload, harvest}
//! under a tight KV budget and reports throughput, reload counts and the
//! fairness penalty relative to FCFS.
//!
//! Run: `cargo bench --bench fair_decode`

use harvest::harvest::{HarvestConfig, HarvestRuntime};
use harvest::kv::KvConfig;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::server::{
    CompletelyFair, Fcfs, Scheduler, SimEngine, SimEngineConfig, SimEngineReport, WorkloadGen,
    WorkloadSpec,
};
use harvest::util::bench::Table;

const CAP_BLOCKS: usize = 48;
const N_REQUESTS: usize = 24;

fn run(use_harvest: bool, sched: &str) -> SimEngineReport {
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let cfg = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: CAP_BLOCKS,
        use_harvest,
        host_backed_peer: false,
    };
    let scheduler: Box<dyn Scheduler> = match sched {
        "fcfs" => Box::new(Fcfs::new()),
        "cf-q4" => Box::new(CompletelyFair::new(4)),
        "cf-q1" => Box::new(CompletelyFair::new(1)),
        _ => unreachable!(),
    };
    let spec = WorkloadSpec {
        n_requests: N_REQUESTS,
        mean_prompt_tokens: 96.0,
        max_new_tokens: 16,
        shared_prefix_fraction: 0.5,
        shared_prefix_tokens: 32,
        ..Default::default()
    };
    let mut eng = SimEngine::new(SimEngineConfig::new(cfg, 8, 32), scheduler, 0);
    eng.run(&mut hr, WorkloadGen::new(spec).generate())
}

fn main() {
    println!(
        "§6.3 — fair decoding under memory pressure ({} requests, {}-block KV pool)\n",
        N_REQUESTS, CAP_BLOCKS
    );
    let table = Table::new(&[10, 10, 12, 10, 12, 14]);
    table.row(&[
        "SCHED".into(),
        "TIER".into(),
        "TOK/S".into(),
        "RELOADS".into(),
        "HIT RATE".into(),
        "CF PENALTY".into(),
    ]);
    table.sep();
    for tier in [false, true] {
        let tier_name = if tier { "peer" } else { "host" };
        let base = run(tier, "fcfs").metrics.tokens_per_sec();
        for sched in ["fcfs", "cf-q4", "cf-q1"] {
            let r = run(tier, sched);
            let tps = r.metrics.tokens_per_sec();
            let penalty = if sched == "fcfs" {
                "-".to_string()
            } else {
                format!("{:.1}%", (1.0 - tps / base) * 100.0)
            };
            table.row(&[
                sched.into(),
                tier_name.into(),
                format!("{tps:.0}"),
                format!("{}", r.kv_stats.reloads()),
                format!("{:.1}%", r.kv_stats.hit_rate() * 100.0),
                penalty,
            ]);
        }
        table.sep();
    }
    println!(
        "(shape target: CF penalty vs FCFS is SMALLER on the peer tier than on\n the host tier — peer-HBM offload as a scheduler robustness mechanism)"
    );
}
