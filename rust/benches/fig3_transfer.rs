//! Fig. 3 — GPU↔GPU vs GPU↔CPU transfer latency of memory chunks of
//! different sizes, mapped to expert sizes of the Table-1 MoE models.
//!
//! Tier-aware edition: each measurement allocates a lease pinned to the
//! tier under test (`Pinned(PeerHbm(1))` vs `Pinned(Host)`) and times a
//! lease-addressed `Transfer::fetch` to the compute GPU — the exact path
//! consumers pay, not a hand-rolled `node.copy`.
//!
//! Paper anchors: speedup ranges from 7.5× (Phi-tiny) to 9.5× (Mixtral).
//!
//! Run: `cargo bench --bench fig3_transfer`

use harvest::harvest::{
    AllocHints, HarvestConfig, HarvestRuntime, MemoryTier, PayloadKind, TierPreference, Transfer,
};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::MOE_MODELS;
use harvest::util::bench::Table;
use harvest::util::{fmt_bytes, fmt_ns};

/// Time one lease-addressed fetch of `bytes` from `tier` to GPU 0 on a
/// fresh node (link FIFO starts idle, matching the paper's isolated
/// microbenchmark).
fn fetch_ns(tier: MemoryTier, bytes: u64) -> u64 {
    let mut hr =
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let session = hr.open_session(PayloadKind::Generic);
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
    let lease = session
        .alloc(&mut hr, bytes, TierPreference::Pinned(tier), hints)
        .expect("fresh node has capacity");
    let report = Transfer::new().fetch(&lease, 0).submit(&mut hr).expect("live lease");
    let ns = report.events[0].duration();
    session.release(&mut hr, lease).expect("live lease");
    ns
}

fn measure(bytes: u64) -> (u64, u64) {
    (fetch_ns(MemoryTier::PeerHbm(1), bytes), fetch_ns(MemoryTier::Host, bytes))
}

fn main() {
    println!("Fig. 3 — GPU<->GPU vs GPU<->CPU transfer latency (tier-aware leases)\n");
    let table = Table::new(&[22, 12, 13, 13, 9, 10]);
    table.row(&[
        "CHUNK".into(),
        "SIZE".into(),
        "GPU<->GPU".into(),
        "CPU<->GPU".into(),
        "SPEEDUP".into(),
        "PAPER".into(),
    ]);
    table.sep();

    // Size sweep (the x-axis of Fig. 3).
    for mib in [1u64, 2, 4, 8, 32, 64, 128, 256, 512] {
        let bytes = mib << 20;
        let (p2p, h2d) = measure(bytes);
        table.row(&[
            format!("{mib} MiB chunk"),
            fmt_bytes(bytes),
            fmt_ns(p2p),
            fmt_ns(h2d),
            format!("{:.1}x", h2d as f64 / p2p as f64),
            "-".into(),
        ]);
    }
    table.sep();

    // Expert-size markers (the labelled points of Fig. 3).
    for m in MOE_MODELS {
        let bytes = m.expert_bytes();
        let (p2p, h2d) = measure(bytes);
        let paper = match m.name {
            "Phi-tiny-MoE" => "7.5x",
            "Mixtral-8x7B" => "9.5x",
            _ => "-",
        };
        table.row(&[
            format!("{} expert", m.name),
            fmt_bytes(bytes),
            fmt_ns(p2p),
            fmt_ns(h2d),
            format!("{:.1}x", h2d as f64 / p2p as f64),
            paper.into(),
        ]);
    }
    println!("\n(testbed model: 2x H100, 12-link NVLink4 vs PCIe 5.0 x16 — DESIGN.md §Calibration)");
}
