//! Fig. 3 — GPU↔GPU vs GPU↔CPU transfer latency of memory chunks of
//! different sizes, mapped to expert sizes of the Table-1 MoE models.
//!
//! Paper anchors: speedup ranges from 7.5× (Phi-tiny) to 9.5× (Mixtral).
//!
//! Run: `cargo bench --bench fig3_transfer`

use harvest::memsim::{DeviceId, NodeSpec, SimNode};
use harvest::moe::MOE_MODELS;
use harvest::util::bench::Table;
use harvest::util::{fmt_bytes, fmt_ns};

fn measure(bytes: u64) -> (u64, u64) {
    // Fresh node per measurement: link FIFO starts idle (matches the
    // paper's isolated microbenchmark).
    let mut node = SimNode::new(NodeSpec::h100x2());
    let p2p = node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), bytes, None).duration();
    let mut node = SimNode::new(NodeSpec::h100x2());
    let h2d = node.copy(DeviceId::Host, DeviceId::Gpu(0), bytes, None).duration();
    (p2p, h2d)
}

fn main() {
    println!("Fig. 3 — GPU<->GPU vs GPU<->CPU transfer latency (virtual time)\n");
    let table = Table::new(&[22, 12, 13, 13, 9, 10]);
    table.row(&[
        "CHUNK".into(),
        "SIZE".into(),
        "GPU<->GPU".into(),
        "CPU<->GPU".into(),
        "SPEEDUP".into(),
        "PAPER".into(),
    ]);
    table.sep();

    // Size sweep (the x-axis of Fig. 3).
    for mib in [1u64, 2, 4, 8, 32, 64, 128, 256, 512] {
        let bytes = mib << 20;
        let (p2p, h2d) = measure(bytes);
        table.row(&[
            format!("{mib} MiB chunk"),
            fmt_bytes(bytes),
            fmt_ns(p2p),
            fmt_ns(h2d),
            format!("{:.1}x", h2d as f64 / p2p as f64),
            "-".into(),
        ]);
    }
    table.sep();

    // Expert-size markers (the labelled points of Fig. 3).
    for m in MOE_MODELS {
        let bytes = m.expert_bytes();
        let (p2p, h2d) = measure(bytes);
        let paper = match m.name {
            "Phi-tiny-MoE" => "7.5x",
            "Mixtral-8x7B" => "9.5x",
            _ => "-",
        };
        table.row(&[
            format!("{} expert", m.name),
            fmt_bytes(bytes),
            fmt_ns(p2p),
            fmt_ns(h2d),
            format!("{:.1}x", h2d as f64 / p2p as f64),
            paper.into(),
        ]);
    }
    println!("\n(testbed model: 2x H100, 12-link NVLink4 vs PCIe 5.0 x16 — DESIGN.md §Calibration)");
}
