//! §7 ablations the paper defers ("broader ablations over cache size,
//! page management policy and scheduling parameters would be valuable"):
//!
//! 1. Cache size: MIG-partition (harvestable budget) sweep for MoE.
//! 2. Page-management policy: LRU / FIFO / LFU / sliding-window switcher
//!    for the KV pool under a prefix-heavy fair-decoding workload.
//! 3. Scheduling parameters: CF quantum sweep.
//! 4. Placement policy: best-fit vs locality / fairness / interference /
//!    stability on a busy 4-GPU domain.
//! 5. Victim policy: LIFO / FIFO / largest / smallest under pressure.
//!
//! Run: `cargo bench --bench ablations`

use harvest::harvest::{
    BestFit, FirstAvailable, HarvestConfig, HarvestRuntime, InterferenceAware, LocalityAware,
    MigConfig, PlacementPolicy, RateLimitFairness, StabilityAware,
};
use harvest::kv::{EvictionPolicy, Fifo, KvConfig, KvOffloadManager, Lfu, Lru, PolicySwitcher};
use harvest::memsim::{NodeSpec, SimNode, TenantLoad};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{find_kv_model, find_moe_model, CgoPipe, ExpertRebalancer, RouterSim};
use harvest::server::{
    CompletelyFair, Fcfs, Scheduler, SimEngine, SimEngineConfig, WorkloadGen, WorkloadSpec,
};
use harvest::util::bench::Table;

const GIB: u64 = 1 << 30;

// ------------------------------------------------------------------
// 1. cache-size sweep
// ------------------------------------------------------------------

fn cache_size_sweep() {
    println!("Ablation 1 — harvestable cache size (MIG partition) vs MoE throughput");
    let model = find_moe_model("mixtral").unwrap();
    let table = Table::new(&[14, 12, 12, 12]);
    table.row(&["PARTITION".into(), "EXPERTS".into(), "TOK/S".into(), "vs CPU".into()]);
    table.sep();
    let cpu = {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let pipe = CgoPipe::paper_setup(model);
        let mut router = RouterSim::new(model, model.n_layers as usize, 9);
        let mut reb = ExpertRebalancer::new(model, 0, 0.5);
        pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Cpu, 4).tokens_per_sec()
    };
    for gib in [0u64, 2, 4, 8, 16, 32, 64] {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.mig[1] = MigConfig::CachePartition { bytes: gib * GIB };
        let mut hr = HarvestRuntime::new(node, cfg);
        let pipe = CgoPipe::paper_setup(model);
        let mut router = RouterSim::new(model, model.n_layers as usize, 9);
        let mut reb = ExpertRebalancer::new(model, 0, 0.5);
        let promoted = reb.rebalance(&mut hr, usize::MAX);
        let t = pipe
            .decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Harvest, 4)
            .tokens_per_sec();
        table.row(&[
            format!("{gib} GiB"),
            format!("{promoted}"),
            format!("{t:.0}"),
            format!("{:+.0}%", (t / cpu - 1.0) * 100.0),
        ]);
    }
    println!("(diminishing returns once the hot expert set fits — cache-size knee)\n");
}

// ------------------------------------------------------------------
// 2. page-management policy
// ------------------------------------------------------------------

fn eviction_policy_sweep() {
    println!("Ablation 2 — KV page-management policy under prefix-heavy CF decoding");
    let table = Table::new(&[16, 12, 12, 12]);
    table.row(&["POLICY".into(), "TOK/S".into(), "RELOADS".into(), "HIT RATE".into()]);
    table.sep();
    let mk_policies = || -> Vec<(&'static str, Box<dyn EvictionPolicy>)> {
        vec![
            ("lru", Box::new(Lru::new())),
            ("fifo", Box::new(Fifo::new())),
            ("lfu", Box::new(Lfu::new())),
            (
                "switcher",
                Box::new(PolicySwitcher::new(
                    vec![Box::new(Lru::new()), Box::new(Lfu::new()), Box::new(Fifo::new())],
                    256,
                    0.05,
                )),
            ),
        ]
    };
    for (name, policy) in mk_policies() {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let cfg = KvConfig {
            model: find_kv_model("kimi").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: 48,
            use_harvest: true,
            host_backed_peer: false,
        };
        let kv = KvOffloadManager::with_policy(cfg, 0, policy);
        let spec = WorkloadSpec {
            n_requests: 24,
            mean_prompt_tokens: 96.0,
            max_new_tokens: 16,
            shared_prefix_fraction: 0.6,
            shared_prefix_tokens: 48,
            ..Default::default()
        };
        let mut eng = SimEngine::with_kv(
            SimEngineConfig::new(cfg, 8, 32),
            Box::new(CompletelyFair::new(1)),
            kv,
        );
        let r = eng.run(&mut hr, WorkloadGen::new(spec).generate());
        table.row(&[
            name.into(),
            format!("{:.0}", r.metrics.tokens_per_sec()),
            format!("{}", r.kv_stats.reloads()),
            format!("{:.1}%", r.kv_stats.hit_rate() * 100.0),
        ]);
    }
    println!("(§8: policy is workload dependent; the switcher hot-swaps by hit rate)\n");
}

// ------------------------------------------------------------------
// 3. CF quantum sweep
// ------------------------------------------------------------------

fn quantum_sweep() {
    println!("Ablation 3 — CF quantum (tokens before rotation) vs throughput & churn");
    let table = Table::new(&[12, 12, 12, 14]);
    table.row(&["QUANTUM".into(), "TOK/S".into(), "RELOADS".into(), "MEAN TTFT ms".into()]);
    table.sep();
    for q in [1u32, 2, 4, 8, 16, 0 /* 0 = fcfs */] {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let cfg = KvConfig {
            model: find_kv_model("kimi").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: 48,
            use_harvest: true,
            host_backed_peer: false,
        };
        let sched: Box<dyn Scheduler> =
            if q == 0 { Box::new(Fcfs::new()) } else { Box::new(CompletelyFair::new(q)) };
        let spec = WorkloadSpec {
            n_requests: 24,
            mean_prompt_tokens: 96.0,
            max_new_tokens: 16,
            shared_prefix_fraction: 0.5,
            shared_prefix_tokens: 32,
            ..Default::default()
        };
        let mut eng = SimEngine::new(SimEngineConfig::new(cfg, 8, 32), sched, 0);
        let r = eng.run(&mut hr, WorkloadGen::new(spec).generate());
        table.row(&[
            if q == 0 { "fcfs".into() } else { format!("q={q}") },
            format!("{:.0}", r.metrics.tokens_per_sec()),
            format!("{}", r.kv_stats.reloads()),
            format!("{:.2}", r.metrics.ttft.mean() / 1e6),
        ]);
    }
    println!("(finer quanta = fairer but more churn; Harvest flattens the cost curve)\n");
}

// ------------------------------------------------------------------
// 4. placement policies
// ------------------------------------------------------------------

fn placement_policy_sweep() {
    println!("Ablation 4 — placement policy on a busy 4-GPU NVLink domain");
    let table = Table::new(&[16, 10, 14, 14]);
    table.row(&["POLICY".into(), "PLACED".into(), "FAILURES".into(), "REVOCATIONS".into()]);
    table.sep();
    let policies: Vec<(&str, fn() -> Box<dyn PlacementPolicy>)> = vec![
        ("best-fit", || Box::new(BestFit)),
        ("first-avail", || Box::new(FirstAvailable)),
        ("locality", || Box::new(LocalityAware)),
        ("fairness", || Box::new(RateLimitFairness { per_client_cap: 64 * GIB })),
        ("interference", || Box::new(InterferenceAware::default())),
        ("stability", || Box::new(StabilityAware)),
    ];
    for (name, mk) in policies {
        // heterogeneous co-tenants: gpu1 placid, gpu2 moderately busy,
        // gpu3 churning hard
        let mut node = SimNode::new(NodeSpec::nvlink_domain(4));
        node.set_tenant_load(1, TenantLoad::constant(80 * GIB, 20 * GIB));
        node.set_tenant_load(2, TenantLoad::constant(80 * GIB, 60 * GIB));
        let churn: Vec<(u64, u64)> = (0..200)
            .map(|i| (i * 500_000, if i % 2 == 0 { 10 * GIB } else { 74 * GIB }))
            .collect();
        node.set_tenant_load(3, TenantLoad::from_steps(80 * GIB, churn));
        let mut hr = HarvestRuntime::with_policy(node, HarvestConfig::for_node(4), mk());

        let model = find_moe_model("mixtral").unwrap();
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        let mut placed = 0usize;
        // interleave placement rounds with time advancing (pressure on
        // gpu3 oscillates every 0.5 ms)
        for step in 0..20u64 {
            placed += reb.rebalance(&mut hr, 16);
            hr.advance_to((step + 1) * 2_000_000);
        }
        table.row(&[
            name.into(),
            format!("{placed}"),
            format!("{}", reb.migration_failures),
            format!("{}", hr.revocations.len()),
        ]);
    }
    println!("(stability avoids the churning peer -> fewer revocations; best-fit packs tightest)\n");
}

// ------------------------------------------------------------------
// 5. victim policies
// ------------------------------------------------------------------

fn victim_policy_sweep() {
    println!("Ablation 5 — victim selection under tenant pressure");
    let table = Table::new(&[16, 14, 16]);
    table.row(&["VICTIM".into(), "REVOCATIONS".into(), "BYTES REVOKED".into()]);
    table.sep();
    for vp in ["lifo", "fifo", "largest", "smallest"] {
        let node = SimNode::new(NodeSpec::h100x2());
        // config-file path: policy sweeps load TOML instead of
        // hand-constructing HarvestConfig
        let cfg = HarvestConfig::from_toml_str(&format!("gpus = 2\nvictim_policy = \"{vp}\""))
            .expect("valid sweep config");
        let mut hr = HarvestRuntime::new(node, cfg);
        // mixed-size allocations: Qwen (16.5 MiB) + Mixtral (336 MiB)
        let qwen = find_moe_model("qwen").unwrap();
        let mixtral = find_moe_model("mixtral").unwrap();
        let mut rq = ExpertRebalancer::new(qwen, 0, 1.0);
        let mut rm = ExpertRebalancer::new(mixtral, 0, 1.0);
        rq.rebalance(&mut hr, 64);
        rm.rebalance(&mut hr, 64);
        // pressure: tenant takes 60 GiB at t=1ms
        hr.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1_000_000, 60 * GIB)]),
        );
        hr.advance_to(2_000_000);
        let bytes: u64 = hr.revocations.iter().map(|r| r.handle.size).sum();
        table.row(&[
            vp.into(),
            format!("{}", hr.revocations.len()),
            harvest::util::fmt_bytes(bytes),
        ]);
    }
    println!("(largest-first frees the budget with the fewest revocation events)\n");
}

// ------------------------------------------------------------------
// 6. when to harvest (§6.2)
// ------------------------------------------------------------------

fn when_to_harvest() {
    println!("Ablation 6 — §6.2 'When to Harvest': reuse x eviction pressure");
    let table = Table::new(&[24, 12, 12, 12]);
    table.row(&["WORKLOAD".into(), "HOST tok/s".into(), "PEER tok/s".into(), "GAIN".into()]);
    table.sep();
    let run = |use_harvest: bool, new_tokens: u32, cap: usize| -> f64 {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let cfg = KvConfig {
            model: find_kv_model("kimi").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap,
            use_harvest,
            host_backed_peer: false,
        };
        let spec = WorkloadSpec {
            n_requests: 24,
            mean_prompt_tokens: 96.0,
            max_new_tokens: new_tokens,
            ..Default::default()
        };
        let mut eng =
            SimEngine::new(SimEngineConfig::new(cfg, 8, 32), Box::new(CompletelyFair::new(1)), 0);
        eng.run(&mut hr, WorkloadGen::new(spec).generate()).metrics.tokens_per_sec()
    };
    // (reuse, pressure) grid: evicted-state reuse scales with decode
    // length (each step re-reads the whole KV); pressure with pool size.
    let cases: [(&str, u32, usize); 4] = [
        ("low reuse, ample mem", 1, 4096),
        ("high reuse, ample mem", 32, 4096),
        ("low reuse, tight mem", 1, 48),
        ("high reuse, tight mem", 32, 48),
    ];
    for (name, new_tokens, cap) in cases {
        let host = run(false, new_tokens, cap);
        let peer = run(true, new_tokens, cap);
        table.row(&[
            name.into(),
            format!("{host:.0}"),
            format!("{peer:.0}"),
            format!("{:+.0}%", (peer / host - 1.0) * 100.0),
        ]);
    }
    println!(
        "(gains need BOTH eviction pressure and reuse of evicted state — the\n high-reuse + tight-memory cell dominates; §6.2's two conditions)\n"
    );
}

fn main() {
    println!("== Harvest ablation suite (§7 / §8 follow-ups) ==\n");
    cache_size_sweep();
    eviction_policy_sweep();
    quantum_sweep();
    placement_policy_sweep();
    victim_policy_sweep();
    when_to_harvest();
}
