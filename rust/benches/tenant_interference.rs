//! Tenant-interference bench: actor-mix sweep over the KV serve path.
//!
//! One serve workload (CF scheduler under a tight local KV pool — the
//! churn-heavy §6.3 regime, where Harvest matters most) is run against
//! escalating closed-loop co-tenant populations:
//!
//! | mix | what it adds |
//! |---|---|
//! | `none` | exogenous-timeline baseline (pre-fleet behavior) |
//! | `inference` | a second inference service (KV-churn allocation + PCIe ingress) |
//! | `training` | ring all-reduce on the serve path's NVLinks + resident model |
//! | `batch` | bursty guaranteed-priority hogs (revocation pressure) |
//! | `mixed` | all three at once |
//!
//! Reported per mix: serve throughput, p99 TTFT, decode stall, KV
//! reloads/recomputes, harvest revocations/demotions and tenant-side
//! counters — i.e. how much each adversary class actually costs the
//! paper's mechanism. A machine-readable summary is written to
//! `BENCH_tenants.json` (see `util::bench::JsonReport`).
//!
//! Run: `cargo bench --bench tenant_interference` (`-- --smoke` for the
//! CI short run).

use harvest::harvest::{HarvestConfig, HarvestRuntime};
use harvest::kv::KvConfig;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::obs::MetricsRegistry;
use harvest::server::{
    CompletelyFair, SimEngine, SimEngineConfig, SimEngineReport, WorkloadGen, WorkloadSpec,
};
use harvest::tenantsim::{TenantFleet, TenantMix};
use harvest::util::bench::{JsonReport, Table};
use harvest::util::fmt_ns;
use harvest::util::json::{obj, Json};

const GIB: u64 = 1 << 30;

fn mix_for(name: &str) -> TenantMix {
    let base = TenantMix {
        enabled: true,
        training: 0,
        inference: 0,
        batch: 0,
        host_gib: 2,
        seed: 42,
        ..TenantMix::default()
    };
    match name {
        "none" => TenantMix { enabled: false, ..base },
        "inference" => TenantMix { inference: 1, ..base },
        "training" => TenantMix { training: 1, ..base },
        "batch" => TenantMix { batch: 2, ..base },
        "mixed" => TenantMix { training: 1, inference: 1, batch: 1, ..base },
        other => unreachable!("unknown mix {other}"),
    }
}

struct MixResult {
    report: SimEngineReport,
    revocations: u64,
    demotions: u64,
}

fn run(mix: &TenantMix, n_requests: usize) -> MixResult {
    let mut hcfg = HarvestConfig::for_node(2);
    hcfg.demote_to_host = true;
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), hcfg);
    let kv = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 192,
        use_harvest: true,
        host_backed_peer: false,
    };
    let cfg = SimEngineConfig::new(kv, 8, 32);
    let mut engine = SimEngine::new(cfg, Box::new(CompletelyFair::new(2)), 0);
    if mix.enabled {
        engine = engine.with_tenants(TenantFleet::from_mix(mix, 2, 80 * GIB, 0));
    }
    let spec = WorkloadSpec {
        n_requests,
        mean_prompt_tokens: 192.0,
        max_new_tokens: 16,
        mean_interarrival_ns: 400_000,
        ..Default::default()
    };
    let report = engine.run(&mut hr, WorkloadGen::new(spec).generate());
    MixResult { report, revocations: hr.revocations.len() as u64, demotions: hr.demotions }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 24 } else { 96 };
    let mut json = JsonReport::new("BENCH_tenants.json");

    println!("tenant interference — actor-mix sweep over the KV serve path ({n} requests)\n");
    let t = Table::new(&[11, 10, 12, 12, 9, 11, 9, 8]);
    t.row(&[
        "MIX".into(),
        "TOK/S".into(),
        "TTFT P99".into(),
        "STALL".into(),
        "RELOADS".into(),
        "REVOKE/DEM".into(),
        "YIELDS".into(),
        "DENIED".into(),
    ]);
    t.sep();
    let mut baseline_tps = 0.0;
    for name in ["none", "inference", "training", "batch", "mixed"] {
        let mix = mix_for(name);
        let r = run(&mix, n);
        let m = &r.report.metrics;
        let s = &r.report.kv_stats;
        let (yields, denied, traffic) = match &r.report.tenant {
            Some(ts) => (ts.broker.lease_yields, ts.denied(), ts.traffic_bytes()),
            None => (0, 0, 0),
        };
        let tps = m.tokens_per_sec();
        t.row(&[
            name.into(),
            format!("{tps:.0}"),
            fmt_ns(m.ttft.percentile(99.0) as u64),
            fmt_ns(m.decode_stall_ns),
            format!("{}", s.reloads()),
            format!("{}/{}", r.revocations, r.demotions),
            format!("{yields}"),
            format!("{denied}"),
        ]);
        assert_eq!(
            m.requests_finished, n as u64,
            "{name}: the serve path must survive its co-tenants"
        );
        // Full registry snapshot per mix: the same serve/kv/broker tree
        // `serve` prints, so rollup tooling reads one shape everywhere.
        let mut reg = MetricsRegistry::new();
        m.register(&mut reg, "serve");
        s.register(&mut reg, "kv");
        if let Some(ts) = &r.report.tenant {
            ts.broker.register(&mut reg, "tenant.broker");
        }
        json.add(
            name,
            obj([
                ("throughput_tps", Json::from(tps)),
                ("ttft_p99_ns", Json::from(m.ttft.percentile(99.0))),
                ("decode_stall_ns", Json::from(m.decode_stall_ns)),
                ("kv_reloads", Json::from(s.reloads())),
                ("kv_recomputes", Json::from(s.recomputes)),
                ("revocations", Json::from(r.revocations)),
                ("demotions", Json::from(r.demotions)),
                ("lease_yields", Json::from(yields)),
                ("tenant_denied", Json::from(denied)),
                ("tenant_traffic_bytes", Json::from(traffic)),
                ("registry", reg.to_json()),
            ]),
        );
        if name == "none" {
            baseline_tps = tps;
        }
    }

    let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
    match json.append_trajectory(&label, smoke) {
        Ok(()) => println!("\nappended point `{label}` to {}", json.path().display()),
        Err(e) => println!("\ncould not write {}: {e}", json.path().display()),
    }
    println!(
        "\ntakeaway: closed-loop tenants cost real throughput (baseline {baseline_tps:.0} tok/s)\n\
         — collectives queue harvest fetches on the shared NVLinks, allocation bursts\n\
         force revocations/demotions — yet every mix serves the full workload: tenants\n\
         always win, and the serve path degrades instead of failing."
    );
}
