//! Fig. 2 — CDF of GPU memory consumption across the (re-synthesised)
//! Alibaba gpu-v2020 cluster trace: 959,080 machine snapshots over 1,800
//! machines / 6,500 GPUs.
//!
//! Paper anchors: ~68% of machines consume <= 20% of GPU memory,
//! ~87% consume <= 50%.
//!
//! Run: `cargo bench --bench fig2_trace_cdf`

use harvest::trace::{ClusterTrace, TraceSpec};
use harvest::util::bench::Table;
use std::time::Instant;

fn main() {
    // Full paper scale: 1800 machines x ~533 snapshots = 959,400.
    let spec = TraceSpec { machines: 1800, snapshots_per_machine: 533, ..TraceSpec::default() };
    let t0 = Instant::now();
    let trace = ClusterTrace::synthesize(spec);
    let took = t0.elapsed();
    println!(
        "Fig. 2 — GPU memory consumption CDF ({} snapshots, synthesized in {:.2?})\n",
        trace.len(),
        took
    );

    let table = Table::new(&[14, 16, 14]);
    table.row(&["UTIL <= x".into(), "MEASURED CDF".into(), "PAPER".into()]);
    table.sep();
    let paper: &[(f64, &str)] = &[
        (0.10, "-"),
        (0.20, "~68%"),
        (0.30, "-"),
        (0.40, "-"),
        (0.50, "~87%"),
        (0.60, "-"),
        (0.70, "-"),
        (0.80, "-"),
        (0.90, "-"),
        (1.00, "100%"),
    ];
    for &(u, paper_val) in paper {
        table.row(&[
            format!("{:.0}%", u * 100.0),
            format!("{:.1}%", trace.cdf_at(u) * 100.0),
            paper_val.into(),
        ]);
    }
    println!("\nmean machine utilisation: {:.1}%", trace.mean_util() * 100.0);

    // Per-machine dispersion (the heterogeneity §2.1 argues creates the
    // harvesting opportunity).
    let means = trace.machine_means();
    let mut sorted = means.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "machine-mean util percentiles: p10 {:.1}%  p50 {:.1}%  p90 {:.1}%",
        harvest::util::stats::percentile_sorted(&sorted, 10.0) * 100.0,
        harvest::util::stats::percentile_sorted(&sorted, 50.0) * 100.0,
        harvest::util::stats::percentile_sorted(&sorted, 90.0) * 100.0,
    );
}
