//! Tier sweep — reload latency per block size across the full cache
//! hierarchy: peer HBM (NVLink) vs CXL-attached memory vs host DRAM
//! (PCIe) vs paged NVMe SSD (staged through host), measured through the
//! same chunked tier-aware lease path the KV manager uses. The table
//! the `TierPreference` cost model is implicitly navigating on every
//! placement decision — five tiers since the cold-tier ladder landed.
//!
//! Run: `cargo bench --bench tier_sweep`

use harvest::harvest::{
    AllocHints, HarvestConfig, HarvestRuntime, MemoryTier, PayloadKind, TierPreference, Transfer,
};
use harvest::kv::manager::RELOAD_CHUNK_BYTES;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::KV_MODELS;
use harvest::util::bench::Table;
use harvest::util::{fmt_bytes, fmt_ns};

const GIB: u64 = 1 << 30;
const ENTRIES: &[u64] = &[100, 1000, 8000];

/// Chunked reload of `bytes` from `tier` to GPU 0 on a fresh node
/// carrying every cold tier (idle links — the unloaded point of the
/// cost model). SSD reloads stage through host DRAM, so they pay the
/// NVMe link plus the PCIe hop the host column pays alone.
fn reload(tier: MemoryTier, bytes: u64) -> u64 {
    let mut hr = HarvestRuntime::new(
        SimNode::new(NodeSpec::h100x2().with_cxl(256 * GIB).with_ssd(1024 * GIB)),
        HarvestConfig::for_node(2),
    );
    let session = hr.open_session(PayloadKind::KvBlock);
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
    let lease = session
        .alloc(&mut hr, bytes, TierPreference::Pinned(tier), hints)
        .expect("fresh node has capacity");
    let report = Transfer::new()
        .chunked(RELOAD_CHUNK_BYTES)
        .fetch(&lease, 0)
        .submit(&mut hr)
        .expect("live lease");
    let ns = report.events[0].duration();
    session.release(&mut hr, lease).expect("live lease");
    ns
}

fn main() {
    println!("Tier sweep — chunked KV reload latency: peer HBM vs CXL vs host DRAM vs SSD\n");
    for m in KV_MODELS {
        println!("{} ({} KiB per KV entry):", m.name, m.kv_bytes_per_token() / 1024);
        let table = Table::new(&[10, 12, 12, 12, 12, 12, 11, 11]);
        table.row(&[
            "ENTRIES".into(),
            "BYTES".into(),
            "PEER HBM".into(),
            "CXL".into(),
            "HOST".into(),
            "SSD".into(),
            "HOST/PEER".into(),
            "SSD/HOST".into(),
        ]);
        table.sep();
        for &n in ENTRIES {
            let bytes = n * m.kv_bytes_per_token();
            let peer = reload(MemoryTier::PeerHbm(1), bytes);
            let cxl = reload(MemoryTier::CxlMem, bytes);
            let host = reload(MemoryTier::Host, bytes);
            let ssd = reload(MemoryTier::Ssd, bytes);
            assert!(
                peer < cxl && cxl < host && host < ssd,
                "tier ordering violated: peer {peer} cxl {cxl} host {host} ssd {ssd}"
            );
            table.row(&[
                format!("{n}"),
                fmt_bytes(bytes),
                fmt_ns(peer),
                fmt_ns(cxl),
                fmt_ns(host),
                fmt_ns(ssd),
                format!("{:.2}x", host as f64 / peer as f64),
                format!("{:.2}x", ssd as f64 / host as f64),
            ]);
        }
        println!();
    }
    println!(
        "(chunked into {} descriptors; CXL sits between the peer and host tiers and\n\
         SSD behind host — exactly the gaps the cold-tier ladder's demote/promote\n\
         migration paths trade across)",
        fmt_bytes(RELOAD_CHUNK_BYTES)
    );
}
