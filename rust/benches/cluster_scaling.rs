//! Scale-out cluster serving bench: aggregate decode throughput vs node
//! count, and router-policy shootout (p99 TTFT) on a shared-prefix
//! session workload.
//!
//! Two tables:
//!
//! 1. **Scaling sweep** — the same batch workload over {1, 2, 4} nodes
//!    behind least-loaded routing. Aggregate tokens/s should rise with
//!    node count (per-node prefill serializes; nodes run in parallel).
//! 2. **Routing shootout** — 4 nodes, a staggered multi-session
//!    workload where 75% of requests reuse one of 8 shared prefixes.
//!    Prefix-affinity routing keeps each session's decode on the node
//!    holding its KV blocks (prefill only the unshared suffix);
//!    round-robin scatters sessions and re-prefills the prefix on every
//!    node — the difference shows up directly in p99 TTFT.
//!
//! A machine-readable summary is written to `BENCH_cluster.json`
//! (see `util::bench::JsonReport`).
//!
//! Run: `cargo bench --bench cluster_scaling` (`-- --smoke` for the CI
//! short run).

use harvest::cluster::{Cluster, ClusterReport, ClusterSpec, RouterPolicy, SchedulerSpec};
use harvest::kv::KvConfig;
use harvest::moe::find_kv_model;
use harvest::obs::MetricsRegistry;
use harvest::server::{SimEngineConfig, WorkloadGen, WorkloadSpec};
use harvest::util::bench::{JsonReport, Table};
use harvest::util::json::{obj, Json};
use harvest::util::fmt_ns;

fn engine(cap_blocks: usize) -> SimEngineConfig {
    let kv = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: cap_blocks,
        use_harvest: true,
        host_backed_peer: false,
    };
    SimEngineConfig::new(kv, 8, 32)
}

fn run(nodes: usize, policy: RouterPolicy, spec: WorkloadSpec) -> ClusterReport {
    let mut cspec = ClusterSpec::new(nodes);
    cspec.router = policy;
    let mut cluster = Cluster::new(&cspec, engine(4_096), SchedulerSpec::Fcfs);
    cluster.run(WorkloadGen::new(spec).generate())
}

fn report_json(r: &ClusterReport) -> Json {
    // Cluster-wide registry snapshot: merged serve metrics (histograms
    // merge bucket-wise, so the p99s here are the true fleet tails) plus
    // the summed tier ledger, in the same shape `serve` prints.
    let mut reg = MetricsRegistry::new();
    r.aggregate.register(&mut reg, "serve");
    r.ledger.register(&mut reg, "ledger");
    obj([
        ("nodes", Json::from(r.per_node.len())),
        ("policy", Json::from(r.router_policy)),
        ("throughput_tps", Json::from(r.aggregate.tokens_per_sec())),
        ("ttft_p50_ns", Json::from(r.aggregate.ttft.percentile(50.0))),
        ("ttft_p99_ns", Json::from(r.aggregate.ttft.percentile(99.0))),
        ("requests_finished", Json::from(r.aggregate.requests_finished)),
        ("shed", Json::from(r.stats.shed)),
        ("prefix_migrations", Json::from(r.stats.prefix_migrations)),
        ("fabric_bytes", Json::from(r.fabric_bytes)),
        ("registry", reg.to_json()),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 32 } else { 128 };
    let mut json = JsonReport::new("BENCH_cluster.json");

    // -- 1. throughput vs node count ----------------------------------
    println!("cluster scaling — aggregate decode throughput vs node count ({n} requests)\n");
    let batch = WorkloadSpec {
        n_requests: n,
        mean_prompt_tokens: 160.0,
        max_new_tokens: 16,
        ..Default::default()
    };
    let t = Table::new(&[8, 12, 12, 12, 10]);
    t.row(&["NODES".into(), "TOK/S".into(), "TTFT P50".into(), "TTFT P99".into(), "SHED".into()]);
    t.sep();
    let mut last = 0.0;
    for nodes in [1usize, 2, 4] {
        let r = run(nodes, RouterPolicy::LeastLoaded, batch);
        let tps = r.aggregate.tokens_per_sec();
        t.row(&[
            format!("{nodes}"),
            format!("{tps:.0}"),
            fmt_ns(r.aggregate.ttft.percentile(50.0) as u64),
            fmt_ns(r.aggregate.ttft.percentile(99.0) as u64),
            format!("{}", r.stats.shed),
        ]);
        json.add(&format!("scaling_nodes_{nodes}"), report_json(&r));
        assert!(r.aggregate.requests_finished == n as u64, "cluster must serve everything");
        if last > 0.0 && tps <= last {
            println!("  !! throughput did not increase from the previous node count");
        }
        last = tps;
    }

    // -- 2. routing policies on a shared-prefix session workload ------
    println!("\nrouting shootout — 4 nodes, 8 sessions, 75% shared-prefix requests\n");
    let sessions = WorkloadSpec {
        n_requests: 2 * n,
        mean_prompt_tokens: 320.0,
        max_new_tokens: 16,
        mean_interarrival_ns: 1_500_000,
        shared_prefix_fraction: 0.75,
        shared_prefix_tokens: 256,
        n_prefix_groups: 8,
        ..Default::default()
    };
    let t = Table::new(&[14, 12, 12, 12, 12, 10]);
    t.row(&[
        "POLICY".into(),
        "TOK/S".into(),
        "TTFT P50".into(),
        "TTFT P99".into(),
        "PFX HITS".into(),
        "MIGR".into(),
    ]);
    t.sep();
    for policy in
        [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::PrefixAffinity]
    {
        let r = run(4, policy, sessions);
        let hits: u64 = r.per_node.iter().map(|p| p.prefix_hits).sum();
        t.row(&[
            policy.name().into(),
            format!("{:.0}", r.aggregate.tokens_per_sec()),
            fmt_ns(r.aggregate.ttft.percentile(50.0) as u64),
            fmt_ns(r.aggregate.ttft.percentile(99.0) as u64),
            format!("{hits}"),
            format!("{}", r.stats.prefix_migrations),
        ]);
        json.add(&format!("routing_{}", policy.name()), report_json(&r));
    }

    let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
    match json.append_trajectory(&label, smoke) {
        Ok(()) => println!("\nappended point `{label}` to {}", json.path().display()),
        Err(e) => println!("\ncould not write {}: {e}", json.path().display()),
    }
    println!(
        "\ntakeaway: nodes scale aggregate decode throughput near-linearly while\n\
         prefix-affinity routing cuts tail TTFT by keeping each session's decode\n\
         on the node that already holds its KV blocks."
    );
}
