//! Fig. 5 — Token-generation throughput improvement with expert weights
//! offloaded to peer GPU (Harvest) vs to CPU (CGOPipe baseline), with 50%
//! of experts forced offloaded.
//!
//! Paper setup (§4.4): MoE-Lightning test bench, µ=324-token micro-batches,
//! b=14 (N=4536), --max-new-tokens=32, 5 trials, 50-token warmup.
//! Paper anchors: +48% … +110% across the four Table-1 models; Phi-3.5
//! nearly doubles Qwen2's speedup.
//!
//! Run: `cargo bench --bench fig5_moe_throughput`

use harvest::harvest::{HarvestConfig, HarvestRuntime};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{CgoPipe, ExpertRebalancer, RouterSim, MOE_MODELS};
use harvest::util::bench::Table;
use harvest::util::stats::mean;

const TRIALS: usize = 5;
const WARMUP_TOKENS: usize = 50;
const NEW_TOKENS: usize = 32;
const OFFLOAD: f64 = 0.5;

/// One trial: warmup + measured decode, exactly like the §4.4 recipe.
fn trial(model: &'static harvest::moe::MoeModel, tier: OffloadTier, seed: u64) -> f64 {
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let pipe = CgoPipe::paper_setup(model);
    let mut router = RouterSim::new(model, model.n_layers as usize, seed);
    let mut reb = ExpertRebalancer::new(model, 0, OFFLOAD);
    if matches!(tier, OffloadTier::Harvest) {
        reb.rebalance(&mut hr, usize::MAX);
    }
    let _warm = pipe.decode_many(&mut router, &mut reb, &mut hr, tier, WARMUP_TOKENS / 10);
    pipe.decode_many(&mut router, &mut reb, &mut hr, tier, NEW_TOKENS).tokens_per_sec()
}

fn main() {
    println!(
        "Fig. 5 — decode throughput, 50% experts offloaded ({} trials, {} new tokens)\n",
        TRIALS, NEW_TOKENS
    );
    let table = Table::new(&[14, 12, 12, 13, 12]);
    table.row(&[
        "MODEL".into(),
        "CPU tok/s".into(),
        "PEER tok/s".into(),
        "IMPROVEMENT".into(),
        "PAPER".into(),
    ]);
    table.sep();
    for m in MOE_MODELS {
        let cpu: Vec<f64> = (0..TRIALS).map(|t| trial(m, OffloadTier::Cpu, t as u64)).collect();
        let peer: Vec<f64> =
            (0..TRIALS).map(|t| trial(m, OffloadTier::Harvest, t as u64)).collect();
        let (c, p) = (mean(&cpu), mean(&peer));
        let paper = match m.name {
            "Mixtral-8x7B" => "~+60%",
            "Phi-3.5-MoE" => "~+110%",
            "Phi-tiny-MoE" => "~+75%",
            "Qwen2-MoE" => "~+48%",
            _ => "-",
        };
        table.row(&[
            m.name.into(),
            format!("{c:.0}"),
            format!("{p:.0}"),
            format!("+{:.0}%", (p / c - 1.0) * 100.0),
            paper.into(),
        ]);
    }
    println!(
        "\n(shape target: every model improves; Phi-3.5 > Qwen2 improvement;\n paper band +48%..+110% — see EXPERIMENTS.md §Fig5 for the calibration gap)"
    );
}
