//! Find the knee: arrival-rate sweep across the stability boundary,
//! static shedding vs the SLO admission controller.
//!
//! One node, fixed service capacity, arrival rate swept from
//! comfortably stable to several times overloaded. Two arms per rate:
//!
//! * **static** — the legacy `shed_queue_depth` gate (router sheds when
//!   the queue hits a fixed depth). Below the knee it never triggers;
//!   past the knee every admitted request still waits behind a full
//!   queue, so p99 TTFT grows super-linearly with load.
//! * **occupancy** — the SLO control plane
//!   (`AdmissionPolicy::SloOccupancy`): the node's controller predicts
//!   queueing wait from the windowed drain rate and sheds *before* the
//!   wait blows the TTFT budget, trading shed rate for a bounded tail.
//!
//! The knee is located from the static arm (first rate whose p99 TTFT
//! exceeds 2x its pre-knee baseline); each occupancy point records
//! whether it held p99 within 2x of its own pre-knee baseline. Past the
//! knee the static tail keeps climbing while the occupancy tail stays
//! flat — that crossover is the whole point of feedback admission.
//!
//! A machine-readable summary is written to `BENCH_find_knee.json`
//! (per-rate records for both arms plus a `knee` summary, see
//! `util::bench::JsonReport`).
//!
//! Run: `cargo bench --bench find_knee` (`-- --smoke` for the CI short
//! run).

use harvest::cluster::{Cluster, ClusterReport, ClusterSpec, SchedulerSpec};
use harvest::control::{AdmissionConfig, AdmissionPolicy, SloConfig};
use harvest::kv::KvConfig;
use harvest::moe::find_kv_model;
use harvest::obs::MetricsRegistry;
use harvest::server::{SimEngineConfig, WorkloadGen, WorkloadSpec};
use harvest::util::bench::{JsonReport, Table};
use harvest::util::fmt_ns;
use harvest::util::json::{obj, Json};

/// Tight single node: small KV pool, 2 decode slots — the stability
/// boundary sits inside the swept rate range.
fn engine() -> SimEngineConfig {
    let kv = KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 48,
        use_harvest: true,
        host_backed_peer: false,
    };
    SimEngineConfig::new(kv, 2, 4)
}

/// TTFT budget sized at roughly twice the healthy (pre-knee) tail: the
/// controller then sheds exactly hard enough to keep the overloaded
/// tail inside the 2x-of-pre-knee band the table checks.
fn slo() -> AdmissionConfig {
    AdmissionConfig {
        slo: SloConfig {
            ttft_p99_ns: 10_000_000, // 10 ms budget
            goodput_floor_tps: 0.0,
            window_ns: 20_000_000,
        },
        high_watermark_pct: 85,
        low_watermark_pct: 60,
    }
}

struct Arm {
    p99_ttft_ns: f64,
    goodput_tok_s: f64,
    finished: u64,
    shed: u64,
    shed_pct: f64,
    /// Tier-ledger subtree from the unified metrics registry (where the
    /// run's harvested bytes landed).
    registry: Json,
}

fn run(admission: AdmissionPolicy, interarrival_ns: u64, n: usize) -> Arm {
    let mut cspec = ClusterSpec::new(1);
    cspec.admission = admission;
    if let AdmissionPolicy::StaticDepth { .. } = admission {
        // The legacy knob the shim inherits: shed at a fixed queue depth.
        cspec.shed_queue_depth = 32;
    }
    let spec = WorkloadSpec {
        n_requests: n,
        mean_prompt_tokens: 128.0,
        max_new_tokens: 24,
        mean_interarrival_ns: interarrival_ns,
        seed: 29,
        ..Default::default()
    };
    let mut cluster = Cluster::new(&cspec, engine(), SchedulerSpec::Fcfs);
    let r: ClusterReport = cluster.run(WorkloadGen::new(spec).generate());
    let shed = r.stats.shed + r.stats.node_shed;
    assert_eq!(
        r.aggregate.requests_finished + shed,
        n as u64,
        "every request must finish or land in a shed ledger"
    );
    let mut reg = MetricsRegistry::new();
    r.ledger.register(&mut reg, "ledger");
    Arm {
        p99_ttft_ns: r.aggregate.ttft.percentile(99.0),
        goodput_tok_s: r.aggregate.goodput_tok_s(),
        finished: r.aggregate.requests_finished,
        shed,
        shed_pct: 100.0 * shed as f64 / n as f64,
        registry: reg.to_json(),
    }
}

fn arm_json(a: &Arm, interarrival_ns: u64) -> Json {
    obj([
        ("interarrival_ns", Json::from(interarrival_ns)),
        ("ttft_p99_ns", Json::from(a.p99_ttft_ns)),
        ("goodput_tok_s", Json::from(a.goodput_tok_s)),
        ("requests_finished", Json::from(a.finished)),
        ("shed", Json::from(a.shed)),
        ("shed_pct", Json::from(a.shed_pct)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 64 } else { 256 };
    let rates: &[u64] = if smoke {
        &[1_200_000, 400_000, 150_000]
    } else {
        &[2_000_000, 1_200_000, 800_000, 500_000, 300_000, 200_000, 150_000]
    };
    let mut json = JsonReport::new("BENCH_find_knee.json");

    println!(
        "find the knee — 1 node, {n} requests per point, interarrival swept \
         {} → {}\n",
        fmt_ns(rates[0]),
        fmt_ns(*rates.last().unwrap())
    );
    let t = Table::new(&[12, 13, 10, 13, 10, 12, 6]);
    t.row(&[
        "ARRIVAL".into(),
        "STATIC P99".into(),
        "SHED%".into(),
        "OCC P99".into(),
        "SHED%".into(),
        "OCC GOODPUT".into(),
        "HELD".into(),
    ]);
    t.sep();

    let mut static_base = 0.0f64;
    // The occupancy arm's pre-knee baseline tracks the *last* rate the
    // static arm still handled — "2x of pre-knee" means 2x the tail you
    // had just before the boundary, not 2x the idle-system tail.
    let mut occ_pre_knee = 1.0f64;
    let mut knee_interarrival: Option<u64> = None;
    let mut held_past_knee = true;
    for (i, &gap) in rates.iter().enumerate() {
        let st = run(AdmissionPolicy::StaticDepth { shed_queue_depth: usize::MAX }, gap, n);
        let oc = run(AdmissionPolicy::SloOccupancy(slo()), gap, n);
        if i == 0 {
            static_base = st.p99_ttft_ns.max(1.0);
        }
        let past_knee = st.p99_ttft_ns > 2.0 * static_base;
        if past_knee && knee_interarrival.is_none() {
            knee_interarrival = Some(gap);
        }
        if !past_knee {
            occ_pre_knee = oc.p99_ttft_ns.max(1.0);
        }
        let held = !past_knee || oc.p99_ttft_ns <= 2.0 * occ_pre_knee;
        if !held {
            held_past_knee = false;
        }
        t.row(&[
            fmt_ns(gap),
            fmt_ns(st.p99_ttft_ns as u64),
            format!("{:.0}%", st.shed_pct),
            fmt_ns(oc.p99_ttft_ns as u64),
            format!("{:.0}%", oc.shed_pct),
            format!("{:.0}", oc.goodput_tok_s),
            if held { "yes".into() } else { "NO".into() },
        ]);
        json.add(&format!("static_{gap}"), arm_json(&st, gap));
        let mut occ = match arm_json(&oc, gap) {
            Json::Obj(o) => o,
            _ => unreachable!("arm_json builds an object"),
        };
        occ.insert("knee_held".into(), Json::Bool(held));
        occ.insert("registry".into(), oc.registry.clone());
        json.add(&format!("occupancy_{gap}"), Json::Obj(occ));
    }

    json.add(
        "knee",
        obj([
            ("static_p99_pre_knee_ns", Json::from(static_base)),
            ("occupancy_p99_pre_knee_ns", Json::from(occ_pre_knee)),
            (
                "knee_interarrival_ns",
                knee_interarrival.map(Json::from).unwrap_or(Json::Null),
            ),
            ("occupancy_held_past_knee", Json::Bool(held_past_knee)),
        ]),
    );
    let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
    match json.append_trajectory(&label, smoke) {
        Ok(()) => println!("\nappended point `{label}` to {}", json.path().display()),
        Err(e) => println!("\ncould not write {}: {e}", json.path().display()),
    }
    match knee_interarrival {
        Some(gap) => println!(
            "\nknee at interarrival {} — past it the static tail climbs super-linearly\n\
             while the occupancy controller {} p99 within 2x of its pre-knee baseline.",
            fmt_ns(gap),
            if held_past_knee { "held" } else { "FAILED to hold" }
        ),
        None => println!(
            "\nno knee inside the swept range — widen the sweep (the static arm never\n\
             exceeded 2x its baseline p99)."
        ),
    }
}
