//! Policy matrix: placement policy × router policy shootout under
//! heterogeneous co-tenant pressure.
//!
//! One 4-node fleet where nodes 2 and 3 run batch-heavy tenant fleets
//! (their harvestable pools churn; nodes 0 and 1 stay quiet), serving a
//! shared-prefix session workload. Every cell of the matrix runs the
//! same workload through a different (placement, router) pair:
//!
//! * placement decides *where inside a node* harvested KV segments go
//!   ([`PlacementSpec`]: best-fit / first-available / stability /
//!   interference);
//! * the router decides *which node* serves each request — including
//!   `harvest-priced`, which scores nodes by priced harvestable
//!   capacity (tier-discounted, churn-discounted) rather than by raw
//!   queue depth.
//!
//! The interesting diagonal: stability-aware placement plus
//! harvest-priced routing should steer work away from the churning
//! nodes *and* keep what lands there on stable devices, showing up as
//! lower p99 TTFT at equal goodput.
//!
//! A machine-readable summary is written to `BENCH_policy_matrix.json`
//! (one record per matrix cell, see `util::bench::JsonReport`).
//!
//! Run: `cargo bench --bench policy_matrix` (`-- --smoke` for the CI
//! short run).

use harvest::cluster::{Cluster, ClusterReport, ClusterSpec, RouterPolicy, SchedulerSpec};
use harvest::harvest::PlacementSpec;
use harvest::kv::KvConfig;
use harvest::moe::find_kv_model;
use harvest::obs::MetricsRegistry;
use harvest::server::{SimEngineConfig, WorkloadGen, WorkloadSpec};
use harvest::tenantsim::TenantMix;
use harvest::util::bench::{JsonReport, Table};
use harvest::util::fmt_ns;
use harvest::util::json::{obj, Json};

fn engine() -> SimEngineConfig {
    let kv = KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 64,
        use_harvest: true,
        host_backed_peer: false,
    };
    SimEngineConfig::new(kv, 4, 8)
}

/// Batch-heavy mix for the churning half of the fleet: one big batch
/// job per node, salted per node so the churn phases differ.
fn churn_mix(node: usize) -> TenantMix {
    TenantMix {
        enabled: true,
        training: 0,
        inference: 0,
        batch: 1,
        batch_gib: 76,
        seed: 3 + node as u64,
        ..Default::default()
    }
}

fn run(placement: PlacementSpec, router: RouterPolicy, spec: WorkloadSpec) -> ClusterReport {
    let mut cspec = ClusterSpec::new(4);
    cspec.router = router;
    cspec.placement = placement;
    cspec.harvest.demote_to_host = true;
    cspec.tenant_overrides.insert(2, churn_mix(2));
    cspec.tenant_overrides.insert(3, churn_mix(3));
    let mut cluster = Cluster::new(&cspec, engine(), SchedulerSpec::CompletelyFair { quantum: 1 });
    cluster.run(WorkloadGen::new(spec).generate())
}

fn cell_json(placement: PlacementSpec, router: RouterPolicy, r: &ClusterReport) -> Json {
    let quiet_routed = r.per_node[0].routed + r.per_node[1].routed;
    // Where the cell's harvested bytes actually landed, straight from
    // the summed tier ledger via the unified registry.
    let mut reg = MetricsRegistry::new();
    r.ledger.register(&mut reg, "ledger");
    obj([
        ("placement", Json::from(placement.name())),
        ("router", Json::from(router.name())),
        ("goodput_tok_s", Json::from(r.aggregate.goodput_tok_s())),
        ("ttft_p50_ns", Json::from(r.aggregate.ttft.percentile(50.0))),
        ("ttft_p99_ns", Json::from(r.aggregate.ttft.percentile(99.0))),
        ("requests_finished", Json::from(r.aggregate.requests_finished)),
        ("quiet_node_routed", Json::from(quiet_routed)),
        ("churn_node_routed", Json::from(r.stats.routed - quiet_routed)),
        ("prefix_migrations", Json::from(r.stats.prefix_migrations)),
        ("fabric_bytes", Json::from(r.fabric_bytes)),
        ("registry", reg.to_json()),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 48 } else { 192 };
    let mut json = JsonReport::new("BENCH_policy_matrix.json");

    let sessions = WorkloadSpec {
        n_requests: n,
        mean_prompt_tokens: 96.0,
        max_new_tokens: 12,
        mean_interarrival_ns: 800_000,
        shared_prefix_fraction: 0.6,
        shared_prefix_tokens: 32,
        n_prefix_groups: 6,
        seed: 17,
        ..Default::default()
    };

    println!(
        "policy matrix — 4 nodes, nodes 2+3 under batch-tenant churn, {n} session requests\n"
    );
    let placements = [
        PlacementSpec::BestFit,
        PlacementSpec::FirstAvailable,
        PlacementSpec::StabilityAware,
        PlacementSpec::parse("interference").unwrap(),
    ];
    let routers =
        [RouterPolicy::LeastLoaded, RouterPolicy::PrefixAffinity, RouterPolicy::HarvestPriced];

    let t = Table::new(&[16, 14, 12, 12, 12, 12]);
    t.row(&[
        "PLACEMENT".into(),
        "ROUTER".into(),
        "GOODPUT".into(),
        "TTFT P50".into(),
        "TTFT P99".into(),
        "QUIET%".into(),
    ]);
    t.sep();
    for placement in placements {
        for router in routers {
            let r = run(placement, router, sessions);
            assert_eq!(
                r.aggregate.requests_finished, n as u64,
                "no admission controller armed — the matrix must serve everything"
            );
            let quiet = r.per_node[0].routed + r.per_node[1].routed;
            t.row(&[
                placement.name().into(),
                router.name().into(),
                format!("{:.0}", r.aggregate.goodput_tok_s()),
                fmt_ns(r.aggregate.ttft.percentile(50.0) as u64),
                fmt_ns(r.aggregate.ttft.percentile(99.0) as u64),
                format!("{:.0}%", 100.0 * quiet as f64 / r.stats.routed.max(1) as f64),
            ]);
            let key = format!("{}__{}", placement.name(), router.name());
            json.add(&key, cell_json(placement, router, &r));
        }
        t.sep();
    }

    let label = std::env::var("BENCH_LABEL").unwrap_or_else(|_| "local".to_string());
    match json.append_trajectory(&label, smoke) {
        Ok(()) => println!("appended point `{label}` to {}", json.path().display()),
        Err(e) => println!("could not write {}: {e}", json.path().display()),
    }
    println!(
        "\ntakeaway: harvest-priced routing shifts load onto the quiet half of the\n\
         fleet (QUIET% up vs least-loaded) because churning nodes price their\n\
         harvestable capacity down; placement then decides how well the work that\n\
         does land on a churning node survives its demotions."
    );
}
