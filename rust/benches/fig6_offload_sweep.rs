//! Fig. 6 — Throughput as a function of expert offload percentage for
//! three representative MoE models, with GPU (Harvest) and CPU offloading.
//!
//! Paper anchors: Qwen2-MoE stays ~975 tok/s from 0% to 100% with GPU
//! offloading while CPU offloading drops to ~810 tok/s at full offload;
//! Mixtral holds ~740 tok/s on GPU vs <600 tok/s on CPU.
//!
//! Run: `cargo bench --bench fig6_offload_sweep`

use harvest::harvest::{HarvestConfig, HarvestRuntime};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{find_moe_model, CgoPipe, ExpertRebalancer, RouterSim};
use harvest::util::bench::Table;

const PASSES: usize = 8;

fn tput(model: &'static harvest::moe::MoeModel, tier: OffloadTier, frac: f64) -> f64 {
    let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let pipe = CgoPipe::paper_setup(model);
    let mut router = RouterSim::new(model, model.n_layers as usize, 42);
    let mut reb = ExpertRebalancer::new(model, 0, frac);
    if matches!(tier, OffloadTier::Harvest) {
        reb.rebalance(&mut hr, usize::MAX);
    }
    let _warm = pipe.decode_many(&mut router, &mut reb, &mut hr, tier, 2);
    pipe.decode_many(&mut router, &mut reb, &mut hr, tier, PASSES).tokens_per_sec()
}

fn main() {
    println!("Fig. 6 — throughput vs expert-offload fraction (tok/s)\n");
    let fracs = [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0];
    // Paper plots Mixtral, Qwen and Phi-tiny ("results for Phi-3.5-MoE
    // are similar to Qwen1.5 and omitted for brevity").
    for name in ["Mixtral-8x7B", "Qwen2-MoE", "Phi-tiny-MoE"] {
        let model = find_moe_model(name).unwrap();
        println!("{name}:");
        let table = Table::new(&[12, 14, 14, 10]);
        table.row(&["OFFLOAD %".into(), "GPU (peer)".into(), "CPU (host)".into(), "GAP".into()]);
        table.sep();
        for &f in &fracs {
            let g = tput(model, OffloadTier::Harvest, f);
            let c = tput(model, OffloadTier::Cpu, f);
            table.row(&[
                format!("{:.1}%", f * 100.0),
                format!("{g:.0}"),
                format!("{c:.0}"),
                format!("{:.2}x", g / c),
            ]);
        }
        println!();
    }
    println!("(shape target: GPU series flat across the sweep, CPU series degrading;\n paper: Qwen ~975 flat vs ~810 CPU at 100%, Mixtral ~740 vs <600)");
}
