//! Future-deployment studies the paper motivates but defers:
//!
//! * §2.2 — "future deployments will increase the size of the NVLink
//!   domain": domain-size sweep of harvestable capacity + MoE speedup.
//! * §7 — NVLink congestion from concurrent model-parallel collectives.
//! * §8 — topology-aware placement (full-mesh vs NVSwitch vs ring) and
//!   CXL-attached memory as an intermediate tier.
//!
//! Run: `cargo bench --bench topology`

use harvest::harvest::{
    BestFit, HarvestConfig, HarvestRuntime, LocalityAware, PlacementPolicy,
};
use harvest::memsim::{
    CollectivePattern, CollectiveTraffic, DeviceId, NodeSpec, SimNode, TenantLoad,
};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{find_moe_model, CgoPipe, ExpertRebalancer, RouterSim};
use harvest::util::bench::Table;
use harvest::util::{fmt_bytes, fmt_ns};

const GIB: u64 = 1 << 30;
const MIB: u64 = 1 << 20;

// ------------------------------------------------------------------
// §2.2 domain-size sweep
// ------------------------------------------------------------------

fn domain_size_sweep() {
    println!("§2.2 — NVLink domain size vs harvestable capacity (busy peers, 74/80 GiB used)");
    let table = Table::new(&[10, 16, 12, 12]);
    table.row(&["GPUS".into(), "HARVESTABLE".into(), "EXPERTS".into(), "TOK/S".into()]);
    table.sep();
    let model = find_moe_model("mixtral").unwrap();
    for n in [2usize, 4, 8, 16, 32] {
        let mut node = SimNode::new(NodeSpec::nvswitch_domain(n));
        for p in 1..n {
            node.set_tenant_load(p, TenantLoad::constant(80 * GIB, 74 * GIB));
        }
        let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(n));
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        let promoted = reb.rebalance(&mut hr, usize::MAX);
        let harvestable: u64 = (1..n).map(|p| hr.node.harvestable_now(p)).sum::<u64>()
            + promoted as u64 * model.expert_bytes();
        let pipe = CgoPipe::paper_setup(model);
        let mut router = RouterSim::new(model, model.n_layers as usize, 3);
        let t = pipe
            .decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Harvest, 2)
            .tokens_per_sec();
        table.row(&[
            format!("{n}"),
            fmt_bytes(harvestable),
            format!("{promoted}/{}", model.n_layers * model.n_experts),
            format!("{t:.0}"),
        ]);
    }
    println!("(larger domains -> more spare HBM in reach -> more of the model cached)\n");
}

// ------------------------------------------------------------------
// §8 fabric comparison + topology-aware placement
// ------------------------------------------------------------------

fn fabric_comparison() {
    println!("§8 — fabric kind x placement policy (8-GPU domain, Mixtral expert fetches)");
    let table = Table::new(&[12, 14, 16, 14]);
    table.row(&[
        "FABRIC".into(),
        "POLICY".into(),
        "MEAN FETCH".into(),
        "vs PCIe".into(),
    ]);
    table.sep();
    let model = find_moe_model("mixtral").unwrap();
    let specs: [(&str, NodeSpec); 3] = [
        ("full-mesh", NodeSpec::nvlink_domain(8)),
        ("nvswitch", NodeSpec::nvswitch_domain(8)),
        ("ring", NodeSpec::ring_domain(8)),
    ];
    for (fname, spec) in specs {
        let policies: Vec<(&str, Box<dyn PlacementPolicy>)> =
            vec![("best-fit", Box::new(BestFit)), ("locality", Box::new(LocalityAware))];
        for (pname, policy) in policies {
            let mut node = SimNode::new(spec.clone());
            // distant peers are tight (small leftover segments attract
            // best-fit); near peers are empty. Topology-blind best-fit
            // therefore places on far peers, which costs hops on a ring.
            for far in [3usize, 4, 5] {
                node.set_tenant_load(far, TenantLoad::constant(80 * GIB, 70 * GIB));
            }
            let mut hr = HarvestRuntime::with_policy(node, HarvestConfig::for_node(8), policy);
            let mut reb = ExpertRebalancer::new(model, 0, 1.0);
            reb.rebalance(&mut hr, 64);
            // measure the serve path: fetch every peer-cached expert once
            let keys: Vec<_> = reb.residency().peer_cached().map(|(k, _, _)| k).collect();
            let mut total: u64 = 0;
            let mut count = 0u64;
            for key in keys {
                let (_, ev) = reb.fetch_expert(&mut hr, key);
                if let Some(ev) = ev {
                    total += ev.duration();
                    count += 1;
                }
            }
            let mean = if count > 0 { total / count } else { 0 };
            let pcie = hr
                .node
                .topo
                .estimate(DeviceId::Host, DeviceId::Gpu(0), model.expert_bytes())
                .unwrap();
            table.row(&[
                fname.into(),
                pname.into(),
                fmt_ns(mean),
                format!("{:.1}x faster", pcie as f64 / mean.max(1) as f64),
            ]);
        }
    }
    println!("(locality-aware placement matters once the fabric is not a full mesh)\n");
}

// ------------------------------------------------------------------
// §7 collective congestion
// ------------------------------------------------------------------

fn collective_congestion() {
    println!("§7 — NVLink congestion from a concurrent tensor-parallel collective");
    let table = Table::new(&[26, 14, 12]);
    table.row(&["BACKGROUND TRAFFIC".into(), "MEAN FETCH".into(), "vs QUIET".into()]);
    table.sep();
    let model = find_moe_model("mixtral").unwrap();
    // Duty cycle of the background allreduce on the shared bridge
    // (Mixtral expert = 336 MiB ≈ 0.8 ms on an idle link):
    //   64 MiB/ms ≈ 15%, 192 MiB/ms ≈ 45%, 320 MiB/ms ≈ 75%.
    // Beyond 100% duty the FIFO queue diverges — not a steady state.
    let loads: [(&str, Option<(u64, u64)>); 4] = [
        ("quiet", None),
        ("allreduce 64 MiB / 1 ms (15%)", Some((64 * MIB, 1_000_000))),
        ("allreduce 192 MiB / 1 ms (45%)", Some((192 * MIB, 1_000_000))),
        ("allreduce 320 MiB / 1 ms (75%)", Some((320 * MIB, 1_000_000))),
    ];
    // A pipeline issues one expert fetch every 2 ms of decode compute.
    const SPACING: u64 = 2_000_000;
    let mut quiet_mean = 0u64;
    for (name, load) in loads {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        reb.rebalance(&mut hr, 32);
        let keys: Vec<_> = reb.residency().peer_cached().map(|(k, _, _)| k).collect();
        let mut coll = load.map(|(bytes, period)| {
            CollectiveTraffic::new(CollectivePattern::RingAllReduce, vec![0, 1], bytes, period)
        });
        let mut total = 0u64;
        let mut n = 0u64;
        for (i, key) in keys.into_iter().enumerate() {
            let issue = i as u64 * SPACING;
            hr.node.clock.advance_to(hr.node.clock.now().max(issue));
            // Inject exactly the collective steps that have *started* by
            // the fetch's issue time (the FIFO link has no reordering, so
            // injecting future steps first would be unfair to the fetch).
            if let Some(c) = coll.as_mut() {
                c.inject_until(&mut hr.node.topo, issue + 1);
            }
            let (_, ev) = reb.fetch_expert(&mut hr, key);
            if let Some(ev) = ev {
                // latency as the pipeline sees it: queueing + transfer
                total += ev.end - issue;
                n += 1;
            }
        }
        let mean = total / n.max(1);
        if load.is_none() {
            quiet_mean = mean;
        }
        table.row(&[
            name.into(),
            fmt_ns(mean),
            format!("{:.2}x", mean as f64 / quiet_mean.max(1) as f64),
        ]);
    }
    println!("(heavy collectives queue ahead of paging and erode the peer tier's advantage)\n");
}

// ------------------------------------------------------------------
// §8 CXL tier
// ------------------------------------------------------------------

fn cxl_tier() {
    println!("§8 — heterogeneous access costs: local HBM / peer NVLink / CXL / host PCIe");
    let table = Table::new(&[22, 14, 10]);
    table.row(&["TIER".into(), "336 MiB FETCH".into(), "RATIO".into()]);
    table.sep();
    let bytes = find_moe_model("mixtral").unwrap().expert_bytes();
    let mut node = SimNode::new(NodeSpec::h100x2());
    let peer = node.copy(DeviceId::Gpu(1), DeviceId::Gpu(0), bytes, None).duration();
    let mut cxl_node = SimNode::new(NodeSpec::h100x2().with_cxl_host());
    let cxl = cxl_node.copy(DeviceId::Host, DeviceId::Gpu(0), bytes, None).duration();
    let mut host_node = SimNode::new(NodeSpec::h100x2());
    let host = host_node.copy(DeviceId::Host, DeviceId::Gpu(0), bytes, None).duration();
    for (name, ns) in [("peer HBM (NVLink)", peer), ("CXL-attached", cxl), ("host DRAM (PCIe)", host)]
    {
        table.row(&[name.into(), fmt_ns(ns), format!("{:.1}x", ns as f64 / peer as f64)]);
    }
    println!("(a NUMA-like pool: policy-driven placement across tiers, peer HBM fastest)\n");
}

fn main() {
    println!("== Harvest topology / future-deployment studies ==\n");
    domain_size_sweep();
    fabric_comparison();
    collective_congestion();
    cxl_tier();
}
