//! Prefetch overlap — decode-stall time with the deadline-aware prefetch
//! pipeline on vs off, on offload-heavy fair-decoding configurations.
//!
//! The §5 transfer-pipeline claim, measured end-to-end: reloads for the
//! sequences the scheduler will run next are issued as background
//! transfers during the current step's compute, so the stall the next
//! step would have paid shrinks (hits) or shortens (late arrivals) —
//! while demand fetches are never queued behind prefetch traffic (the
//! planner yields on busy links).
//!
//! Run: `cargo bench --bench prefetch_overlap`

use harvest::harvest::{HarvestConfig, HarvestRuntime, PrefetchConfig};
use harvest::kv::KvConfig;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::server::{
    CompletelyFair, SimEngine, SimEngineConfig, SimEngineReport, WorkloadGen, WorkloadSpec,
};
use harvest::util::bench::Table;
use harvest::util::fmt_ns;

/// Offload-heavy fair-decoding run: `n` requests rotating through 8
/// decode slots against a `cap`-block local pool.
fn run(model: &'static str, cap: usize, n: usize, prefetch: bool) -> SimEngineReport {
    let mut hr =
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let kv = KvConfig {
        model: find_kv_model(model).unwrap(),
        block_tokens: 16,
        local_capacity_blocks: cap,
        use_harvest: true,
        host_backed_peer: false,
    };
    let mut cfg = SimEngineConfig::new(kv, 8, 16);
    if prefetch {
        cfg = cfg.with_prefetch(PrefetchConfig::default());
    }
    let spec = WorkloadSpec {
        n_requests: n,
        mean_prompt_tokens: 96.0,
        max_new_tokens: 16,
        ..Default::default()
    };
    let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
    eng.run(&mut hr, WorkloadGen::new(spec).generate())
}

fn main() {
    println!("Prefetch overlap — decode stall, prefetch OFF vs ON");
    println!("(CF quantum=1, 8 slots, 16 requests; offload-heavy local pools)\n");
    for model in ["deepseek", "kimi", "mistral-large"] {
        println!("{model}:");
        let table = Table::new(&[6, 12, 12, 8, 7, 6, 6, 7, 9, 9]);
        table.row(&[
            "CAP".into(),
            "STALL OFF".into(),
            "STALL ON".into(),
            "DELTA".into(),
            "HITS".into(),
            "LATE".into(),
            "WASTE".into(),
            "YIELD".into(),
            "TPS OFF".into(),
            "TPS ON".into(),
        ]);
        table.sep();
        for cap in [48usize, 64, 96] {
            let off = run(model, cap, 16, false);
            let on = run(model, cap, 16, true);
            let pf = on.metrics.prefetch.clone().unwrap_or_default();
            let delta = if off.metrics.decode_stall_ns == 0 {
                0.0
            } else {
                100.0
                    * (off.metrics.decode_stall_ns as f64 - on.metrics.decode_stall_ns as f64)
                    / off.metrics.decode_stall_ns as f64
            };
            table.row(&[
                format!("{cap}"),
                fmt_ns(off.metrics.decode_stall_ns),
                fmt_ns(on.metrics.decode_stall_ns),
                format!("-{delta:.0}%"),
                format!("{}", pf.hits),
                format!("{}", pf.late),
                format!("{}", pf.wasted),
                format!("{}", pf.yielded),
                format!("{:.0}", off.metrics.tokens_per_sec()),
                format!("{:.0}", on.metrics.tokens_per_sec()),
            ]);
        }
        println!();
    }
    println!("(prefetch never delays demand: the planner admits background transfers");
    println!(" only on links without queued demand traffic, completing by the next");
    println!(" step's start — see harvest::prefetch)");
}
