//! Property tests for the observability plane over real serving runs.
//!
//! Rather than synthetic event streams, these run an instrumented
//! engine workload (tight pool, SLO admission under overload) and
//! check structural invariants of whatever the run recorded:
//!
//! * every span is well-formed (`end >= start`, bounded args);
//! * per-node stepper timelines are nondecreasing in virtual time;
//! * the bounded ring evicts oldest-first — a small-ring run records
//!   exactly the tail of the same run with an unbounded ring;
//! * admission decision instants reconcile one-for-one with the
//!   controller's own `AdmissionStats` counters;
//! * Chrome trace export round-trips through `util::json`.

use harvest::cluster::SchedulerSpec;
use harvest::control::{AdmissionConfig, AdmissionStats, SloConfig};
use harvest::harvest::{HarvestConfig, HarvestRuntime};
use harvest::kv::KvConfig;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::obs::trace::{self, Subsystem, TraceEvent, MAX_ARGS};
use harvest::server::{SimEngine, SimEngineConfig, WorkloadGen, WorkloadSpec};

fn kv_cfg(cap_blocks: usize) -> KvConfig {
    KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: cap_blocks,
        use_harvest: true,
        host_backed_peer: false,
    }
}

fn admission() -> AdmissionConfig {
    AdmissionConfig {
        slo: SloConfig {
            ttft_p99_ns: 5_000_000,
            goodput_floor_tps: 0.0,
            window_ns: 10_000_000,
        },
        high_watermark_pct: 85,
        low_watermark_pct: 60,
    }
}

/// One deterministic overloaded engine run, traced with a ring of
/// `ring_cap`. Returns the recorded events, the admission controller's
/// own counters, and how many events the ring evicted.
fn traced_run(ring_cap: usize) -> (Vec<TraceEvent>, AdmissionStats, u64) {
    trace::enable(ring_cap);
    let mut hr =
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let cfg = SimEngineConfig::new(kv_cfg(32), 2, 4).with_admission(admission());
    let mut eng = SimEngine::new(cfg, SchedulerSpec::Fcfs.build(), 0);
    let spec = WorkloadSpec {
        n_requests: 48,
        mean_prompt_tokens: 128.0,
        max_new_tokens: 16,
        mean_interarrival_ns: 150_000,
        seed: 23,
        ..Default::default()
    };
    let _ = eng.run(&mut hr, WorkloadGen::new(spec).generate());
    let stats = eng.stepper().admission_stats().expect("controller is armed");
    let dropped = trace::dropped();
    let events = trace::take();
    trace::disable();
    (events, stats, dropped)
}

#[test]
fn spans_are_well_formed() {
    let (events, _, dropped) = traced_run(1 << 20);
    assert_eq!(dropped, 0, "ring must be big enough for the whole run");
    assert!(!events.is_empty());
    for ev in &events {
        assert!(ev.end >= ev.start, "span {} ends before it starts", ev.name);
        assert!(ev.args().len() <= MAX_ARGS);
        if !ev.is_span() {
            assert_eq!(ev.start, ev.end, "instant {} has a duration", ev.name);
        }
    }
}

/// The stepper emits its `kv_sync` span once per step, anchored at the
/// step's start — per node, those anchors never go backwards in the
/// ring's record order. (Virtual time is monotone per node even though
/// different subsystems interleave freely.)
#[test]
fn stepper_virtual_time_is_nondecreasing_per_node() {
    let (events, _, _) = traced_run(1 << 20);
    let mut last_start: std::collections::BTreeMap<u32, u64> = Default::default();
    let mut seen = 0u64;
    for ev in events.iter().filter(|e| e.sub == Subsystem::Stepper && e.name == "kv_sync") {
        let last = last_start.entry(ev.node).or_insert(0);
        assert!(
            ev.start >= *last,
            "node {} stepped backwards: {} after {}",
            ev.node,
            ev.start,
            last
        );
        *last = ev.start;
        seen += 1;
    }
    assert!(seen > 10, "expected many steps, saw {seen}");
}

/// Oldest-first eviction, end to end: a small ring holds exactly the
/// tail of the identical run recorded with a large ring, and the
/// dropped counter accounts for every missing event.
#[test]
fn ring_eviction_drops_oldest_first() {
    let (all, _, dropped_all) = traced_run(1 << 20);
    assert_eq!(dropped_all, 0);
    const CAP: usize = 64;
    assert!(all.len() > CAP, "run must overflow the small ring");
    let (tail, _, dropped_tail) = traced_run(CAP);
    assert_eq!(tail.len(), CAP);
    assert_eq!(dropped_tail as usize, all.len() - CAP);
    assert_eq!(tail.as_slice(), &all[all.len() - CAP..], "ring kept non-tail events");
}

/// Every admission decision leaves exactly one instant in the
/// `admission` lane, so the lane reconciles with the controller's own
/// counters — the trace is an audit log of the control plane, not a
/// sampling of it.
#[test]
fn admission_instants_reconcile_with_stats() {
    let (events, stats, dropped) = traced_run(1 << 20);
    assert_eq!(dropped, 0, "reconciliation needs the complete event stream");
    let count = |name: &str| {
        events.iter().filter(|e| e.sub == Subsystem::Admission && e.name == name).count() as u64
    };
    assert_eq!(count("admit"), stats.admitted);
    assert_eq!(count("defer"), stats.defer_events);
    assert_eq!(count("shed"), stats.shed);
    assert!(
        stats.admitted > 0 && stats.shed > 0,
        "overload case must both admit and shed, got {stats:?}"
    );
}

/// Chrome export is valid JSON that survives a parse → print round trip
/// through `util::json`, with one trace event per recorded event plus
/// the process/thread metadata header.
#[test]
fn chrome_export_round_trips_through_json() {
    let (events, _, _) = traced_run(1 << 20);
    let exported = trace::to_chrome_json(&events);
    let text = exported.to_string();
    let reparsed = harvest::util::json::Json::parse(&text).expect("export must parse");
    assert_eq!(reparsed.to_string(), text, "parse → print must be a fixed point");

    let arr = reparsed.get("traceEvents").unwrap().as_arr().unwrap();
    let nodes: std::collections::BTreeSet<u32> = events.iter().map(|e| e.node).collect();
    // Per node: 1 process_name + 8 thread_name metadata events.
    assert_eq!(arr.len(), events.len() + nodes.len() * 9);
    assert_eq!(
        reparsed.get("displayTimeUnit").unwrap().as_str().unwrap(),
        "ms"
    );
}
