//! Cross-module integration tests: config → node → harvest → MoE/KV
//! serving paths, exercised end-to-end in virtual time. These check the
//! *shape* of the paper's headline results on the calibrated simulator
//! (Fig. 3 / 5 / 6 / 7 bands, §6.3 fair-decoding interaction), plus
//! failure-injection scenarios no single module covers.

use harvest::config::{find_preset, DeploymentConfig, WorkloadKind};
use harvest::harvest::{
    AllocHints, HarvestConfig, HarvestRuntime, MemoryTier, MigConfig, PayloadKind,
    PrefetchConfig, RevocationReason, TierPreference, Transfer,
};
use harvest::kv::{KvConfig, KvOffloadManager, SeqId};
use harvest::memsim::{DeviceId, NodeSpec, SimNode, TenantLoad};
use harvest::moe::pipeline::OffloadTier;
use harvest::moe::{find_kv_model, find_moe_model, CgoPipe, ExpertRebalancer, RouterSim};
use harvest::server::{
    CompletelyFair, Fcfs, Scheduler, SimEngine, SimEngineConfig, WorkloadGen, WorkloadSpec,
};
use harvest::trace::{ClusterTrace, TraceSpec};

const GIB: u64 = 1 << 30;

fn hr2() -> HarvestRuntime {
    HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2))
}

// ---------------------------------------------------------------------
// Fig. 3: transfer-latency ratio band
// ---------------------------------------------------------------------

#[test]
fn fig3_expert_sized_chunks_hit_speedup_band() {
    // The paper reports 7.5× (Phi-tiny, 16.5 MiB) to 9.5× (Mixtral,
    // 336 MiB). Check each Table-1 expert size lands in a band around it.
    for m in harvest::moe::MOE_MODELS {
        let bytes = m.expert_bytes();
        let node = SimNode::new(NodeSpec::h100x2());
        let p2p = node.topo.estimate(DeviceId::Gpu(1), DeviceId::Gpu(0), bytes).unwrap();
        let h2d = node.topo.estimate(DeviceId::Host, DeviceId::Gpu(0), bytes).unwrap();
        let speedup = h2d as f64 / p2p as f64;
        assert!(
            (6.5..=10.5).contains(&speedup),
            "{}: {} -> {speedup:.1}x outside Fig. 3 band",
            m.name,
            bytes
        );
    }
}

// ---------------------------------------------------------------------
// Fig. 5: MoE decode throughput improvement at 50% offload
// ---------------------------------------------------------------------

#[test]
fn fig5_all_models_improve_at_half_offload() {
    for name in ["mixtral", "phi-3.5", "phi-tiny", "qwen"] {
        let model = find_moe_model(name).unwrap();
        let pipe = CgoPipe::paper_setup(model);

        let mut hr = hr2();
        let mut router = RouterSim::new(model, model.n_layers as usize, 7);
        let mut reb = ExpertRebalancer::new(model, 0, 0.5);
        reb.rebalance(&mut hr, usize::MAX);
        let h = pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Harvest, 4);

        let mut hr = hr2();
        let mut router = RouterSim::new(model, model.n_layers as usize, 7);
        let mut reb = ExpertRebalancer::new(model, 0, 0.5);
        let c = pipe.decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Cpu, 4);

        let improvement = h.tokens_per_sec() / c.tokens_per_sec() - 1.0;
        // Paper band: +48% … +110%. The simulator lands in a wider band
        // (EXPERIMENTS.md §Fig5 discusses the calibration gap) but the
        // shape holds: every model improves substantially, none regresses.
        assert!(
            (0.25..=2.2).contains(&improvement),
            "{name}: improvement {:.0}% outside band",
            improvement * 100.0
        );
    }
}

#[test]
fn fig5_phi35_beats_qwen_improvement() {
    // §4.5: Phi-3.5-MoE nearly doubles Qwen2-MoE's speedup because of
    // higher expert reuse (fewer experts, smaller fan-out).
    let improvement = |name: &str| {
        let model = find_moe_model(name).unwrap();
        let pipe = CgoPipe::paper_setup(model);
        let run = |tier| {
            let mut hr = hr2();
            let mut router = RouterSim::new(model, model.n_layers as usize, 7);
            let mut reb = ExpertRebalancer::new(model, 0, 0.5);
            if matches!(tier, OffloadTier::Harvest) {
                reb.rebalance(&mut hr, usize::MAX);
            }
            pipe.decode_many(&mut router, &mut reb, &mut hr, tier, 3).tokens_per_sec()
        };
        run(OffloadTier::Harvest) / run(OffloadTier::Cpu)
    };
    let phi = improvement("phi-3.5");
    let qwen = improvement("qwen");
    assert!(phi > qwen, "phi {phi:.2}x <= qwen {qwen:.2}x");
}

// ---------------------------------------------------------------------
// Fig. 6: offload-fraction sweep shape
// ---------------------------------------------------------------------

#[test]
fn fig6_gpu_flat_cpu_degrades() {
    let model = find_moe_model("qwen").unwrap();
    let pipe = CgoPipe::paper_setup(model);
    let tput = |tier: OffloadTier, frac: f64| {
        let mut hr = hr2();
        let mut router = RouterSim::new(model, model.n_layers as usize, 11);
        let mut reb = ExpertRebalancer::new(model, 0, frac);
        if matches!(tier, OffloadTier::Harvest) {
            reb.rebalance(&mut hr, usize::MAX);
        }
        pipe.decode_many(&mut router, &mut reb, &mut hr, tier, 2).tokens_per_sec()
    };
    let gpu0 = tput(OffloadTier::Harvest, 0.0);
    let gpu100 = tput(OffloadTier::Harvest, 1.0);
    let cpu0 = tput(OffloadTier::Cpu, 0.0);
    let cpu100 = tput(OffloadTier::Cpu, 1.0);
    // GPU offload stays within ~12% of its 0% point (paper: "nearly
    // constant at approximately 975 tokens/s").
    let gpu_drop = 1.0 - gpu100 / gpu0;
    assert!(gpu_drop < 0.12, "GPU offload dropped {:.0}%", gpu_drop * 100.0);
    // CPU offload loses noticeably more (paper: Qwen 975 → ~810 tok/s;
    // the simulator degrades more steeply at full offload — see
    // EXPERIMENTS.md §Fig6 — but the qualitative gap is what Fig. 6
    // demonstrates: GPU flat, CPU degrading).
    let cpu_drop = 1.0 - cpu100 / cpu0;
    assert!(cpu_drop > gpu_drop + 0.10, "cpu drop {cpu_drop:.2} vs gpu drop {gpu_drop:.2}");
    assert!((0.10..=0.90).contains(&cpu_drop), "cpu drop {:.0}%", cpu_drop * 100.0);
}

#[test]
fn fig6_monotone_cpu_degradation() {
    let model = find_moe_model("mixtral").unwrap();
    let pipe = CgoPipe::paper_setup(model);
    let mut last = f64::INFINITY;
    for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut hr = hr2();
        let mut router = RouterSim::new(model, model.n_layers as usize, 3);
        let mut reb = ExpertRebalancer::new(model, 0, frac);
        let t = pipe
            .decode_many(&mut router, &mut reb, &mut hr, OffloadTier::Cpu, 2)
            .tokens_per_sec();
        assert!(t <= last * 1.02, "cpu offload tput rose at frac {frac}: {t:.0} > {last:.0}");
        last = t;
    }
}

// ---------------------------------------------------------------------
// Fig. 7: KV reload latency, peer vs host
// ---------------------------------------------------------------------

#[test]
fn fig7_kv_reload_speedup_band() {
    for (name, lo, hi) in
        [("kimi", 4.0, 7.0), ("deepseek", 4.0, 7.0), ("mistral-large", 2.5, 7.0)]
    {
        let model = find_kv_model(name).unwrap();
        for entries in [100u64, 1000, 8000] {
            let bytes = entries * model.kv_bytes_per_token();
            let chunks = bytes.div_ceil(harvest::kv::manager::RELOAD_CHUNK_BYTES).max(1);
            let mut node = SimNode::new(NodeSpec::h100x2());
            let p2p = node.copy_scattered(DeviceId::Gpu(1), DeviceId::Gpu(0), bytes, chunks, None);
            let mut node = SimNode::new(NodeSpec::h100x2());
            let h2d = node.copy_scattered(DeviceId::Host, DeviceId::Gpu(0), bytes, chunks, None);
            let speedup = (h2d.duration()) as f64 / (p2p.duration()) as f64;
            assert!(
                (lo..=hi).contains(&speedup),
                "{name} @ {entries} entries: {speedup:.2}x outside [{lo}, {hi}]"
            );
        }
    }
}

// ---------------------------------------------------------------------
// §6.3 fair decoding + harvest as scheduler-robustness mechanism
// ---------------------------------------------------------------------

fn kv_run(
    use_harvest: bool,
    scheduler: Box<dyn Scheduler>,
    cap_blocks: usize,
    n_requests: usize,
) -> harvest::server::SimEngineReport {
    let mut hr = hr2();
    let cfg = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: cap_blocks,
        use_harvest,
        host_backed_peer: false,
    };
    let spec = WorkloadSpec {
        n_requests,
        mean_prompt_tokens: 96.0,
        max_new_tokens: 16,
        shared_prefix_fraction: 0.5,
        shared_prefix_tokens: 32,
        ..Default::default()
    };
    let mut eng = SimEngine::new(SimEngineConfig::new(cfg, 8, 32), scheduler, 0);
    eng.run(&mut hr, WorkloadGen::new(spec).generate())
}

#[test]
fn fair_decoding_penalty_shrinks_with_harvest() {
    // CF pays a throughput penalty vs FCFS under tight memory; Harvest
    // must shrink that penalty (§6.3: "reduces the performance penalty of
    // fairness-oriented scheduling").
    let cap = 48;
    let n = 24;
    let fcfs_host = kv_run(false, Box::new(Fcfs::new()), cap, n).metrics.tokens_per_sec();
    let cf_host =
        kv_run(false, Box::new(CompletelyFair::new(1)), cap, n).metrics.tokens_per_sec();
    let fcfs_peer = kv_run(true, Box::new(Fcfs::new()), cap, n).metrics.tokens_per_sec();
    let cf_peer =
        kv_run(true, Box::new(CompletelyFair::new(1)), cap, n).metrics.tokens_per_sec();
    let penalty_host = 1.0 - cf_host / fcfs_host;
    let penalty_peer = 1.0 - cf_peer / fcfs_peer;
    assert!(penalty_host > 0.0, "CF must cost something under pressure (host)");
    assert!(
        penalty_peer < penalty_host,
        "harvest should shrink the CF penalty: host {:.1}% vs peer {:.1}%",
        penalty_host * 100.0,
        penalty_peer * 100.0
    );
}

#[test]
fn all_requests_complete_under_churn_and_revocation() {
    // Tenant pressure oscillates while CF churns the KV working set:
    // requests must all finish, tokens must be conserved.
    let mut hr = hr2();
    // Oscillate every 10 ms across the whole run (prefill of 16×~80-token
    // prompts plus decode spans tens of ms of virtual time).
    let steps: Vec<(u64, u64)> =
        (0..20).map(|i| (i * 10_000_000, if i % 2 == 1 { 80 * GIB } else { 0 })).collect();
    hr.node.set_tenant_load(1, TenantLoad::from_steps(80 * GIB, steps));
    let cfg = KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 32,
        use_harvest: true,
        host_backed_peer: false,
    };
    let n = 16usize;
    let new_tokens = 12u32;
    let spec = WorkloadSpec {
        n_requests: n,
        mean_prompt_tokens: 80.0,
        max_new_tokens: new_tokens,
        ..Default::default()
    };
    let mut eng =
        SimEngine::new(SimEngineConfig::new(cfg, 4, 16), Box::new(CompletelyFair::new(1)), 0);
    let report = eng.run(&mut hr, WorkloadGen::new(spec).generate());
    assert_eq!(report.metrics.requests_finished, n as u64);
    assert_eq!(report.metrics.tokens_generated, n as u64 * new_tokens as u64);
    // the oscillation must actually have caused revocations
    assert!(!hr.revocations.is_empty(), "test intended to exercise revocation but none happened");
}

// ---------------------------------------------------------------------
// Deadline-aware prefetch pipeline (overlap peer DMA with decode)
// ---------------------------------------------------------------------

#[test]
fn prefetch_overlap_reduces_decode_stall_on_offload_heavy_config() {
    // Acceptance: the prefetch-enabled run shows lower decode-stall time
    // on an offload-heavy configuration, completes the same work, and
    // never hurts throughput beyond noise.
    let run = |prefetch: bool| {
        let mut hr = hr2();
        let cfg = KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: 60,
            use_harvest: true,
            host_backed_peer: false,
        };
        let mut ecfg = harvest::server::SimEngineConfig::new(cfg, 8, 16);
        if prefetch {
            ecfg = ecfg.with_prefetch(PrefetchConfig::default());
        }
        let spec = WorkloadSpec {
            n_requests: 16,
            mean_prompt_tokens: 96.0,
            max_new_tokens: 16,
            ..Default::default()
        };
        let mut eng = SimEngine::new(ecfg, Box::new(CompletelyFair::new(1)), 0);
        eng.run(&mut hr, WorkloadGen::new(spec).generate())
    };
    let off = run(false);
    let on = run(true);
    assert!(off.metrics.decode_stall_ns > 0, "offload-heavy baseline must stall");
    assert!(
        on.metrics.decode_stall_ns < off.metrics.decode_stall_ns,
        "prefetch-on stall {} >= prefetch-off {}",
        on.metrics.decode_stall_ns,
        off.metrics.decode_stall_ns
    );
    let pf = on.metrics.prefetch.as_ref().expect("ledger present");
    assert!(pf.hits > 0, "{pf:?}");
    assert_eq!(on.metrics.requests_finished, off.metrics.requests_finished);
    assert!(on.metrics.tokens_per_sec() >= off.metrics.tokens_per_sec() * 0.95);
}

#[test]
fn prefetch_traffic_recorded_in_monitor_and_visible_to_interference_policy() {
    let mut hr = hr2();
    let cfg = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 8,
        use_harvest: true,
        host_backed_peer: false,
    };
    let mut kv = KvOffloadManager::new(cfg, 0).with_prefetch(PrefetchConfig::default());
    let s = SeqId(1);
    for _ in 0..(16 * 12) {
        kv.append_token(&mut hr, s); // 12 blocks vs 8 slots: spills to peer
    }
    // let spill DMA finish so the fetch link is demand-free
    hr.advance_to(hr.node.clock.now() + 50_000_000);
    assert_eq!(hr.monitor().prefetch_bytes_on(1), 0);
    let demand_before = hr.monitor().demand_bytes_on(1);
    assert!(demand_before > 0, "spill populates are demand traffic");

    let plan = kv.plan_prefetch(&mut hr, &[s]);
    assert!(!plan.is_empty());
    let deadline = hr.node.clock.now() + 1_000_000_000;
    let issued = kv.submit_prefetch(&mut hr, &plan, deadline);
    assert!(issued > 0);

    // Background traffic is attributed as prefetch...
    let pf_bytes = hr.monitor().prefetch_bytes_on(1);
    assert_eq!(pf_bytes, issued as u64 * kv.cfg.block_bytes());
    // ...without polluting the demand counter (evictions made room, so
    // demand bytes may grow, but never by the prefetched amount)...
    assert!(hr.monitor().demand_bytes_on(1) >= demand_before);
    // ...and the interference policy's bandwidth signal sees it.
    let views = hr.peer_views();
    assert!(
        views[1].bw_demand > 0.0,
        "interference signal must include prefetch traffic"
    );
    kv.check_invariants().unwrap();
}

// ---------------------------------------------------------------------
// MIG isolation through the full MoE path
// ---------------------------------------------------------------------

#[test]
fn mig_partition_caps_expert_promotion() {
    let model = find_moe_model("mixtral").unwrap(); // 336 MiB experts
    let node = SimNode::new(NodeSpec::h100x2());
    let mut cfg = HarvestConfig::for_node(2);
    cfg.mig[1] = MigConfig::CachePartition { bytes: 2 * GIB };
    let mut hr = HarvestRuntime::new(node, cfg);
    let mut reb = ExpertRebalancer::new(model, 0, 1.0);
    let promoted = reb.rebalance(&mut hr, usize::MAX);
    // 2 GiB / 336 MiB ≈ 6 experts max
    assert!(promoted >= 4 && promoted <= 6, "promoted {promoted}");
    assert!(hr.live_bytes_on(1) <= 2 * GIB);
}

#[test]
fn mig_reclaim_revokes_all_and_pipeline_falls_back() {
    let model = find_moe_model("phi-tiny").unwrap();
    let node = SimNode::new(NodeSpec::h100x2());
    let mut cfg = HarvestConfig::for_node(2);
    cfg.mig[1] = MigConfig::CachePartition { bytes: 4 * GIB };
    let mut hr = HarvestRuntime::new(node, cfg);
    let pipe = CgoPipe::paper_setup(model);
    let mut router = RouterSim::new(model, model.n_layers as usize, 5);
    let mut reb = ExpertRebalancer::new(model, 0, 0.5);
    reb.rebalance(&mut hr, usize::MAX);
    let before = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
    assert!(before.fetches_peer > 0);
    // operator reclaims the MIG instance
    hr.revoke_peer(1, RevocationReason::ExternalReclaim);
    let after = pipe.decode_pass(&mut router, &mut reb, &mut hr, OffloadTier::Harvest);
    assert_eq!(after.fetches_peer, 0, "no peer fetches after reclaim");
    assert!(after.fetches_host > 0, "falls back to host");
    assert!(
        after.tokens_per_sec() < before.tokens_per_sec(),
        "losing the cache tier must cost throughput"
    );
}

// ---------------------------------------------------------------------
// Larger NVLink domains (§2.2 future deployments)
// ---------------------------------------------------------------------

#[test]
fn more_peers_harvest_more_experts() {
    let model = find_moe_model("mixtral").unwrap();
    let promoted_with = |n_gpus: usize, tenant_gib: u64| {
        let node = SimNode::new(NodeSpec::nvlink_domain(n_gpus));
        let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(n_gpus));
        for p in 1..n_gpus {
            hr.node.set_tenant_load(p, TenantLoad::constant(80 * GIB, tenant_gib * GIB));
        }
        let mut reb = ExpertRebalancer::new(model, 0, 1.0);
        reb.rebalance(&mut hr, usize::MAX)
    };
    // busy peers: 76/80 GiB used -> ~12 experts per peer
    let two = promoted_with(2, 76);
    let four = promoted_with(4, 76);
    let eight = promoted_with(8, 76);
    assert!(two < four && four < eight, "{two} {four} {eight}");
}

// ---------------------------------------------------------------------
// Config-driven launches
// ---------------------------------------------------------------------

#[test]
fn preset_kv_launch_runs_end_to_end() {
    let cfg = find_preset("fair-decode").unwrap();
    assert_eq!(cfg.workload, WorkloadKind::KvOffload);
    let mut hr = HarvestRuntime::new(SimNode::new(cfg.node_spec()), cfg.harvest_config());
    let kv = cfg.kv_config().unwrap();
    let mut eng = SimEngine::new(
        SimEngineConfig::new(kv, cfg.decode_slots, cfg.max_running),
        Box::new(CompletelyFair::new(cfg.quantum)),
        0,
    );
    let mut spec = cfg.workload_spec();
    spec.n_requests = 12; // keep the test fast
    let report = eng.run(&mut hr, WorkloadGen::new(spec).generate());
    assert_eq!(report.metrics.requests_finished, 12);
    assert!(report.use_harvest);
}

#[test]
fn config_file_roundtrip_drives_same_workload() {
    let cfg = find_preset("paper-moe").unwrap();
    let text = cfg.to_toml();
    let dir = std::env::temp_dir().join(format!("harvest-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("deploy.toml");
    std::fs::write(&path, &text).unwrap();
    let loaded = DeploymentConfig::from_file(&path).unwrap();
    assert_eq!(loaded.moe_model, cfg.moe_model);
    assert_eq!(loaded.workload, WorkloadKind::MoeOffload);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Fig. 2 cluster trace anchors
// ---------------------------------------------------------------------

#[test]
fn fig2_trace_cdf_matches_paper_anchors() {
    let trace = ClusterTrace::synthesize(TraceSpec::default());
    // Paper: ~68% of machines <= 20% util, ~87% <= 50%.
    let at20 = trace.cdf_at(0.20);
    let at50 = trace.cdf_at(0.50);
    assert!((0.60..=0.76).contains(&at20), "CDF@20% = {at20:.2}");
    assert!((0.80..=0.94).contains(&at50), "CDF@50% = {at50:.2}");
}

// ---------------------------------------------------------------------
// Durability modes through the full stack
// ---------------------------------------------------------------------

#[test]
fn lossy_kv_block_recomputes_after_revocation() {
    let mut hr = hr2();
    let cfg = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 4,
        use_harvest: true,
        host_backed_peer: false, // lossy peer tier
    };
    let mut kv = KvOffloadManager::new(cfg, 0);
    let s = SeqId(1);
    // overflow the local pool so blocks spill to peer
    for _ in 0..16 * 16 {
        kv.append_token(&mut hr, s);
    }
    let peer_blocks = {
        let t = kv.table();
        t.seq_blocks(s)
            .iter()
            .filter(|&&b| t.residency(b).map(|r| r.is_peer()).unwrap_or(false))
            .count()
    };
    assert!(peer_blocks > 0, "spill to peer expected");
    // revoke the peer tier entirely
    hr.revoke_peer(1, RevocationReason::TenantPressure);
    let recomputes_before = kv.stats.recomputes;
    kv.access_seq(&mut hr, s);
    assert!(
        kv.stats.recomputes > recomputes_before,
        "lossy blocks must be recomputed after revocation"
    );
    kv.check_invariants().unwrap();
}

#[test]
fn host_backed_kv_block_reloads_from_host_after_revocation() {
    let mut hr = hr2();
    let cfg = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 4,
        use_harvest: true,
        host_backed_peer: true, // durable: host copy materialised on evict
    };
    let mut kv = KvOffloadManager::new(cfg, 0);
    let s = SeqId(1);
    for _ in 0..16 * 16 {
        kv.append_token(&mut hr, s);
    }
    hr.revoke_peer(1, RevocationReason::TenantPressure);
    let host_reloads_before = kv.stats.host_reloads;
    let recomputes_before = kv.stats.recomputes;
    kv.access_seq(&mut hr, s);
    assert!(kv.stats.host_reloads > host_reloads_before, "expected host reloads");
    assert_eq!(kv.stats.recomputes, recomputes_before, "host-backed never recomputes");
}

// ---------------------------------------------------------------------
// Harvest API contract seen by applications
// ---------------------------------------------------------------------

#[test]
fn compute_gpu_is_never_selected_as_peer() {
    let node = SimNode::new(NodeSpec::nvlink_domain(4));
    let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(4));
    let session = hr.open_session(PayloadKind::Generic);
    let mut held = Vec::new();
    for compute in 0..4usize {
        for _ in 0..8 {
            let lease = session
                .alloc(
                    &mut hr,
                    GIB,
                    TierPreference::PEER_ONLY,
                    AllocHints { compute_gpu: Some(compute), ..Default::default() },
                )
                .unwrap();
            assert_ne!(
                lease.tier(),
                MemoryTier::PeerHbm(compute),
                "allocated on the compute GPU"
            );
            held.push(lease);
        }
    }
    drop(held);
    assert_eq!(hr.sweep_leaked(), 32, "dropped leases all reclaimed");
    for p in 0..4 {
        assert_eq!(hr.live_bytes_on(p), 0);
    }
}

// ---------------------------------------------------------------------
// The redesigned revocation pipeline, observed end-to-end
// ---------------------------------------------------------------------

#[test]
fn revocation_pipeline_drains_and_invalidates_before_event_observable() {
    // §3.2 ordering through the pull-model API: when `drain_revocations`
    // hands over an event, the in-flight DMA touching the region has
    // been drained and the placement invalidated *already*.
    let mut hr = hr2();
    let session = hr.open_session(PayloadKind::KvBlock);
    let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
    let lease = session.alloc(&mut hr, 256 * (1 << 20), TierPreference::PEER_ONLY, hints).unwrap();
    let id = lease.id();
    // long in-flight copy tagged with the lease
    let fill = Transfer::new().populate(&lease, DeviceId::Host).submit(&mut hr).unwrap();
    assert!(fill.end > hr.node.clock.now(), "copy still in flight");
    // co-tenant pressure revokes it
    hr.node.set_tenant_load(1, TenantLoad::from_steps(80 * GIB, vec![(0, 0), (1, 80 * GIB)]));
    hr.advance_to(2);
    // BEFORE draining: placement is gone, bytes are free
    assert!(!hr.is_live(id), "invalidated before the event is observable");
    assert_eq!(hr.node.gpus[1].hbm.used(), 0, "freed before the event is observable");
    let events = session.drain_revocations(&mut hr);
    assert_eq!(events.len(), 1);
    assert_eq!(events[0].lease, id);
    assert_eq!(events[0].kind, PayloadKind::KvBlock);
    assert_eq!(events[0].reason, RevocationReason::TenantPressure);
    assert!(
        events[0].at >= fill.end,
        "drain-DMA precedes the event: at={} < copy end={}",
        events[0].at,
        fill.end
    );
    // exactly once
    assert!(session.drain_revocations(&mut hr).is_empty());
    drop(lease);
    assert_eq!(hr.sweep_leaked(), 0, "revoked lease is not double-freed by the sweep");
}

#[test]
fn kv_multi_block_admission_is_all_or_nothing() {
    // Acceptance: a KV admission batch that does not fully fit on the
    // peer rolls back completely — no partial placement — and the whole
    // batch takes the host path instead.
    let node = SimNode::new(NodeSpec::h100x2());
    let kv_cfg = KvConfig {
        model: find_kv_model("kimi").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: 6,
        use_harvest: true,
        host_backed_peer: false,
    };
    let mut hcfg = HarvestConfig::for_node(2);
    // space for 2 blocks on the peer; the batch below needs 5
    hcfg.mig[1] = MigConfig::CachePartition { bytes: 2 * kv_cfg.block_bytes() };
    let mut hr = HarvestRuntime::new(node, hcfg);
    let mut kv = KvOffloadManager::new(kv_cfg, 0);
    let s = SeqId(1);
    for _ in 0..(16 * 6) {
        kv.append_token(&mut hr, s); // fills the local pool exactly
    }
    assert_eq!(kv.stats.evictions_to_peer + kv.stats.evictions_to_host, 0);
    kv.reserve_local(&mut hr, 5); // vectored admission of 5 victims
    assert_eq!(kv.stats.evictions_to_peer, 0, "no partial peer placement");
    assert_eq!(kv.stats.evictions_to_host, 5, "entire batch fell back to host");
    assert_eq!(kv.stats.peer_alloc_failures, 1, "one vectored policy consultation");
    assert_eq!(hr.live_bytes_on(1), 0, "rollback left no bytes on the peer");
    assert_eq!(hr.node.gpus[1].hbm.used(), 0);
    kv.check_invariants().unwrap();
    // …and when the batch fits, it lands wholesale on the peer:
    let mut hr_roomy = hr2();
    let mut kv2 = KvOffloadManager::new(kv_cfg, 0);
    for _ in 0..(16 * 6) {
        kv2.append_token(&mut hr_roomy, s);
    }
    kv2.reserve_local(&mut hr_roomy, 5);
    assert_eq!(kv2.stats.evictions_to_peer, 5, "one all-or-nothing batch admitted");
    assert_eq!(kv2.stats.evictions_to_host, 0);
    assert_eq!(hr_roomy.live_bytes_on(1), 5 * kv_cfg.block_bytes());
    kv2.check_invariants().unwrap();
}

// ---------------------------------------------------------------------
// Cluster serving (scale-out): affinity, scaling, TOML-selected routing
// ---------------------------------------------------------------------

mod cluster_serving {
    use super::*;
    use harvest::cluster::{Cluster, ClusterSpec, RouterPolicy, SchedulerSpec};
    use std::collections::BTreeMap;

    fn cluster_engine(cap_blocks: usize, slots: usize, max_running: usize) -> SimEngineConfig {
        let kv = KvConfig {
            model: find_kv_model("kimi").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: cap_blocks,
            use_harvest: true,
            host_backed_peer: false,
        };
        SimEngineConfig::new(kv, slots, max_running)
    }

    /// Staggered session workload: every request reuses one of `groups`
    /// shared prefixes.
    fn session_workload(
        n: usize,
        groups: usize,
        prefix: u32,
        gap_ns: u64,
    ) -> Vec<harvest::server::Request> {
        WorkloadGen::new(WorkloadSpec {
            n_requests: n,
            mean_prompt_tokens: prefix as f64 + 32.0,
            prompt_sigma: 0.2,
            max_new_tokens: 16,
            mean_interarrival_ns: gap_ns,
            shared_prefix_fraction: 1.0,
            shared_prefix_tokens: prefix,
            n_prefix_groups: groups,
            ..Default::default()
        })
        .generate()
    }

    #[test]
    fn affinity_routing_keeps_decode_on_the_node_holding_kv_blocks() {
        let mut spec = ClusterSpec::new(3);
        spec.router = RouterPolicy::PrefixAffinity;
        let mut cluster = Cluster::new(&spec, cluster_engine(4_096, 8, 32), SchedulerSpec::Fcfs);
        let reqs = session_workload(36, 3, 64, 3_000_000);
        let report = cluster.run(reqs.clone());
        assert_eq!(report.aggregate.requests_finished, 36);
        assert_eq!(report.stats.shed, 0);
        // Every group was pinned to exactly one node...
        let mut group_node: BTreeMap<u32, usize> = BTreeMap::new();
        for req in &reqs {
            let g = req.prefix_group.expect("all requests share a prefix");
            let node = report.node_of(req.id).expect("request served");
            let holder = *group_node.entry(g).or_insert(node);
            assert_eq!(holder, node, "group {g} decoded off its KV-holder node");
        }
        // ...and that node really holds the group's prefix KV blocks in
        // its own KV manager; the others never built them.
        for (&g, &holder) in &group_node {
            for i in 0..cluster.n_nodes() {
                let node = cluster.node(i);
                if i == holder {
                    let seq = node.prefix_seq(g).expect("holder caches the prefix");
                    assert!(
                        !node.kv_manager().table().seq_blocks(seq).is_empty(),
                        "holder's prefix sequence has no KV blocks"
                    );
                } else {
                    assert!(
                        node.prefix_seq(g).is_none(),
                        "node {i} built prefix {g} it never needed (no spillover configured)"
                    );
                }
            }
        }
        // All but the first request per group prefilled against the cache.
        let hits: u64 = report.per_node.iter().map(|n| n.prefix_hits).sum();
        assert_eq!(hits, 36 - group_node.len() as u64);
    }

    #[test]
    fn affinity_beats_round_robin_p99_ttft_on_shared_prefix_workload() {
        // 4 nodes, 2 long-prefix sessions, arrivals paced so queues stay
        // shallow: TTFT is dominated by prefill. Round-robin re-builds
        // every prefix on every node (groups x nodes full prefills);
        // affinity pays one full prefill per group and serves the rest
        // from the holder's cache — the tail collapses.
        let run = |policy: RouterPolicy| {
            let mut spec = ClusterSpec::new(4);
            spec.router = policy;
            let mut cluster =
                Cluster::new(&spec, cluster_engine(8_192, 8, 32), SchedulerSpec::Fcfs);
            cluster.run(session_workload(256, 2, 256, 6_000_000))
        };
        let rr = run(RouterPolicy::RoundRobin);
        let aff = run(RouterPolicy::PrefixAffinity);
        assert_eq!(rr.aggregate.requests_finished, 256);
        assert_eq!(aff.aggregate.requests_finished, 256);
        let rr_p99 = rr.aggregate.ttft.percentile(99.0);
        let aff_p99 = aff.aggregate.ttft.percentile(99.0);
        assert!(
            aff_p99 < rr_p99 * 0.7,
            "affinity p99 ttft {aff_p99:.0} ns not well under round-robin {rr_p99:.0} ns"
        );
        // affinity also does strictly more cache reuse
        let rr_hits: u64 = rr.per_node.iter().map(|n| n.prefix_hits).sum();
        let aff_hits: u64 = aff.per_node.iter().map(|n| n.prefix_hits).sum();
        assert!(aff_hits > rr_hits, "affinity hits {aff_hits} <= rr hits {rr_hits}");
    }

    #[test]
    fn aggregate_decode_throughput_increases_with_node_count() {
        // The cluster_scaling bench's headline, pinned as a test: the
        // same batch workload over 1 -> 2 -> 4 nodes raises aggregate
        // tokens/s (per-node prefill serializes; nodes run in parallel).
        let tps = |nodes: usize| {
            let mut spec = ClusterSpec::new(nodes);
            spec.router = RouterPolicy::LeastLoaded;
            let mut cluster =
                Cluster::new(&spec, cluster_engine(4_096, 8, 32), SchedulerSpec::Fcfs);
            let reqs = WorkloadGen::new(WorkloadSpec {
                n_requests: 64,
                mean_prompt_tokens: 160.0,
                max_new_tokens: 16,
                ..Default::default()
            })
            .generate();
            let report = cluster.run(reqs);
            assert_eq!(report.aggregate.requests_finished, 64);
            report.aggregate.tokens_per_sec()
        };
        let one = tps(1);
        let two = tps(2);
        let four = tps(4);
        assert!(two > one * 1.4, "2 nodes {two:.0} <= 1.4 x 1 node {one:.0}");
        assert!(four > two * 1.4, "4 nodes {four:.0} <= 1.4 x 2 nodes {two:.0}");
    }

    #[test]
    fn harvest_priced_beats_least_loaded_p99_ttft_under_heterogeneous_tenants() {
        use harvest::tenantsim::TenantMix;

        // Nodes 2 and 3 host a guaranteed batch tenant bursting to
        // nearly the whole peer GPU; nodes 0 and 1 run idle. Least-loaded
        // balances queue depths blindly, so half the fleet lands on nodes
        // whose peer tier is gone and churning (demotions, host reloads).
        // Harvest-priced routing sees the missing harvestable bytes and
        // the churn discount and steers around the contended pair.
        let run = |policy: RouterPolicy| {
            let mut spec = ClusterSpec::new(4);
            spec.router = policy;
            spec.harvest.demote_to_host = true;
            for node in [2usize, 3] {
                spec.tenant_overrides.insert(
                    node,
                    TenantMix {
                        enabled: true,
                        training: 0,
                        inference: 0,
                        batch: 1,
                        batch_gib: 76,
                        seed: 3 + node as u64,
                        ..Default::default()
                    },
                );
            }
            let kv = KvConfig {
                model: find_kv_model("deepseek").unwrap(),
                block_tokens: 16,
                // tight pool: decode spills into the harvest tiers
                local_capacity_blocks: 24,
                use_harvest: true,
                host_backed_peer: false,
            };
            let engine = SimEngineConfig::new(kv, 4, 8);
            let mut cluster =
                Cluster::new(&spec, engine, SchedulerSpec::CompletelyFair { quantum: 1 });
            let reqs = WorkloadGen::new(WorkloadSpec {
                n_requests: 96,
                mean_prompt_tokens: 96.0,
                max_new_tokens: 12,
                mean_interarrival_ns: 800_000,
                ..Default::default()
            })
            .generate();
            cluster.run(reqs)
        };
        let ll = run(RouterPolicy::LeastLoaded);
        let hp = run(RouterPolicy::HarvestPriced);
        assert_eq!(ll.aggregate.requests_finished, 96);
        assert_eq!(hp.aggregate.requests_finished, 96);
        assert_eq!(hp.stats.shed + hp.stats.node_shed, 0, "routing test must not shed");
        // Harvest-priced demonstrably shifts work onto the idle pair...
        let idle_routed = |r: &harvest::cluster::ClusterReport| {
            r.per_node[0].routed + r.per_node[1].routed
        };
        assert!(
            idle_routed(&hp) > idle_routed(&ll),
            "harvest-priced routed {} requests to the idle pair, least-loaded {}",
            idle_routed(&hp),
            idle_routed(&ll)
        );
        // ...and the TTFT tail tightens.
        let ll_p99 = ll.aggregate.ttft.percentile(99.0);
        let hp_p99 = hp.aggregate.ttft.percentile(99.0);
        assert!(
            hp_p99 < ll_p99,
            "harvest-priced p99 ttft {hp_p99:.0} ns not under least-loaded {ll_p99:.0} ns"
        );
    }

    #[test]
    fn slo_admission_holds_p99_ttft_where_static_shedding_collapses() {
        // The find_knee bench's headline, pinned as a test: push one
        // node past its stability boundary (arrivals faster than it
        // drains). The static gate admits everything up to a depth it
        // cannot justify, so admitted requests queue without bound and
        // the p99 TTFT grows with the backlog. The SLO controller sheds
        // the excess and holds the tail near its budget.
        use harvest::control::{AdmissionConfig, AdmissionPolicy, SloConfig};

        let slo = SloConfig {
            ttft_p99_ns: 30_000_000, // 30 ms
            goodput_floor_tps: 0.0,
            window_ns: 20_000_000,
        };
        let run = |admission: AdmissionPolicy| {
            let mut spec = ClusterSpec::new(1);
            spec.admission = admission;
            let kv = KvConfig {
                model: find_kv_model("deepseek").unwrap(),
                block_tokens: 16,
                local_capacity_blocks: 48,
                use_harvest: true,
                host_backed_peer: false,
            };
            // 2 decode slots, long decodes, arrivals every 150 µs: far
            // past the knee for this service rate.
            let engine = SimEngineConfig::new(kv, 2, 4);
            let mut cluster = Cluster::new(&spec, engine, SchedulerSpec::Fcfs);
            let reqs = WorkloadGen::new(WorkloadSpec {
                n_requests: 160,
                mean_prompt_tokens: 128.0,
                max_new_tokens: 24,
                mean_interarrival_ns: 150_000,
                ..Default::default()
            })
            .generate();
            cluster.run(reqs)
        };
        let occupancy = run(AdmissionPolicy::SloOccupancy(AdmissionConfig {
            slo,
            high_watermark_pct: 85,
            low_watermark_pct: 60,
        }));
        let legacy = run(AdmissionPolicy::StaticDepth { shed_queue_depth: usize::MAX });
        let total = |r: &harvest::cluster::ClusterReport| {
            r.aggregate.requests_finished + r.stats.shed + r.stats.node_shed
        };
        assert_eq!(total(&occupancy), 160, "every arrival served or shed exactly once");
        assert_eq!(total(&legacy), 160);
        assert_eq!(legacy.stats.shed + legacy.stats.node_shed, 0, "unbounded gate never sheds");
        let held = occupancy.aggregate.ttft.percentile(99.0);
        let collapsed = legacy.aggregate.ttft.percentile(99.0);
        assert!(
            occupancy.stats.node_shed > 0,
            "past the knee the controller must shed some load"
        );
        assert!(
            held < collapsed,
            "SLO admission p99 ttft {held:.0} ns not under the unbounded gate's \
             {collapsed:.0} ns"
        );
        // the survivors still make real progress (arrivals run ~16x the
        // service rate here, so most of the load *should* shed — but a
        // controller that sheds everything defeats the point)
        assert!(occupancy.aggregate.requests_finished >= 8, "over-shedding defeats the point");
    }

    #[test]
    fn router_policy_and_cluster_shape_selectable_from_toml() {
        // End-to-end: TOML text -> DeploymentConfig -> ClusterSpec ->
        // served workload, for every policy spelling.
        for (spelling, expect) in [
            ("round-robin", RouterPolicy::RoundRobin),
            ("least-loaded", RouterPolicy::LeastLoaded),
            ("affinity", RouterPolicy::PrefixAffinity),
        ] {
            let toml = format!(
                "workload = \"kv\"\n[cluster]\nnodes = 2\nrouter_policy = \"{spelling}\"\n\
                 [requests]\nn = 8\n[kv]\nmodel = \"Kimi-K2\"",
            );
            let cfg = DeploymentConfig::from_toml(&toml).unwrap();
            assert_eq!(cfg.router_policy, expect);
            let engine = SimEngineConfig::new(
                cfg.kv_config().unwrap(),
                cfg.decode_slots,
                cfg.max_running,
            );
            let mut cluster =
                Cluster::new(&cfg.cluster_spec(), engine, cfg.scheduler_spec().unwrap());
            let report = cluster.run(WorkloadGen::new(cfg.workload_spec()).generate());
            assert_eq!(report.router_policy, expect.name());
            assert_eq!(report.aggregate.requests_finished, 8);
            assert_eq!(report.per_node.len(), 2);
        }
    }
}

// ---------------------------------------------------------------------
// Tenant actors (closed-loop co-tenants): NVLink interference,
// burst-driven revocation/demotion, replay compatibility
// ---------------------------------------------------------------------

mod tenant_actors {
    use super::*;
    use harvest::tenantsim::{BatchActor, TenantFleet, TenantPriority, TrainingActor};

    const MIB: u64 = 1 << 20;

    /// A training actor's ring all-reduce rides the same NVLink FIFOs as
    /// harvest DMA, so a demand fetch queues behind it: the link's
    /// busy-until horizon (the `queue_ns` term every `TierView` exposes
    /// to placement policies) grows, and the fetch measurably slows.
    #[test]
    fn training_collective_delays_harvest_peer_fetches() {
        let fetch_with = |training: bool| {
            let mut hr = hr2();
            let s = hr.open_session(PayloadKind::KvBlock);
            let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
            let lease = s
                .alloc(&mut hr, 256 * MIB, TierPreference::PEER_ONLY, hints)
                .unwrap();
            assert_eq!(lease.tier(), MemoryTier::PeerHbm(1));
            let mut fleet = TenantFleet::new();
            if training {
                // 512 MiB per participant per 1 ms step: more than the
                // link drains per period, so a backlog builds.
                fleet.push(Box::new(TrainingActor::new(
                    "train-0",
                    vec![0, 1],
                    GIB,
                    0,
                    0,
                    512 * MIB,
                    1_000_000,
                )));
            }
            fleet.advance_to(&mut hr, 10_000_000);
            let now = hr.node.clock.now();
            let queue_ns = hr
                .node
                .topo
                .busy_until(DeviceId::Gpu(1), DeviceId::Gpu(0))
                .saturating_sub(now);
            let report = Transfer::new().fetch(&lease, 0).submit(&mut hr).unwrap();
            let duration = report.end - now;
            s.release(&mut hr, lease).unwrap();
            if training {
                assert!(fleet.stats().traffic_bytes() > 0, "collective must inject");
            }
            (queue_ns, duration)
        };
        let (quiet_queue, quiet) = fetch_with(false);
        let (congested_queue, congested) = fetch_with(true);
        assert_eq!(quiet_queue, 0, "no tenant -> idle NVLink");
        assert!(congested_queue > 0, "collective backlog must be queue-visible");
        assert!(
            congested > quiet,
            "fetch behind the collective ({congested} ns) must be slower than quiet \
             ({quiet} ns)"
        );
    }

    /// End-to-end through `SimEngine::run`: a guaranteed-priority batch
    /// tenant bursting to full GPU capacity forces the controller to
    /// revoke/demote the KV manager's peer leases mid-serve — and with
    /// `demote_to_host` on, every displaced block survives on the host
    /// tier (no recompute), while all requests still finish.
    #[test]
    fn tenant_burst_triggers_revocation_and_demotion_through_engine() {
        let run = |with_tenant: bool| {
            let mut hcfg = HarvestConfig::for_node(2);
            hcfg.demote_to_host = true;
            let mut hr = HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), hcfg);
            let kv = KvConfig {
                model: find_kv_model("deepseek").unwrap(),
                block_tokens: 16,
                local_capacity_blocks: 32,
                use_harvest: true,
                host_backed_peer: false,
            };
            let cfg = SimEngineConfig::new(kv, 4, 16);
            let mut eng = SimEngine::new(cfg, Box::new(CompletelyFair::new(1)), 0);
            if with_tenant {
                let mut fleet = TenantFleet::new();
                // Bursts claim the whole peer GPU: nothing short of
                // evicting every harvest lease satisfies them.
                fleet.push(Box::new(BatchActor::new(
                    "batch-0",
                    1,
                    80 * GIB,
                    2_000_000,
                    2_000_000,
                    TenantPriority::Guaranteed,
                    3,
                )));
                eng = eng.with_tenants(fleet);
            }
            let reqs = WorkloadGen::new(WorkloadSpec {
                n_requests: 12,
                mean_prompt_tokens: 64.0,
                max_new_tokens: 8,
                ..Default::default()
            })
            .generate();
            let report = eng.run(&mut hr, reqs);
            (report, hr.demotions, hr.revocations.len())
        };
        let (quiet, quiet_demotions, quiet_revocations) = run(false);
        assert_eq!(quiet.metrics.requests_finished, 12);
        assert_eq!(quiet_demotions + quiet_revocations as u64, 0, "no tenant, no pressure");
        let (report, demotions, _) = run(true);
        assert_eq!(report.metrics.requests_finished, 12, "tenant bursts must not kill serving");
        let tenant = report.tenant.as_ref().expect("fleet stats reported");
        assert!(tenant.broker.lease_yields >= 1, "bursts must displace harvest leases");
        assert_eq!(tenant.broker.oom_with_harvest, 0, "tenants always win");
        assert!(demotions > 0, "lossy KV leases demote under demote_to_host");
        assert!(report.kv_stats.demotions > 0, "KV manager observes the demotions");
        assert_eq!(report.kv_stats.recomputes, 0, "demoted blocks are never lost");
        assert!(
            report.kv_stats.host_reloads > 0,
            "demoted blocks reload from their host-tier lease"
        );
    }

    /// The full cold-tier ladder under a full-pressure tenant burst:
    /// with `compress_before_demote` armed the controller compresses the
    /// KV manager's peer leases in place, then demotes the (still
    /// over-budget) compressed leases; the aging sweep writes them back
    /// to the paged SSD arena; and the decode path brings every block
    /// home with **zero recomputes**, paying the modeled decompression
    /// cost instead. With the ladder off, the same burst drops the lossy
    /// leases and the serve path pays recomputes.
    #[test]
    fn tenant_burst_drives_cold_tier_ladder_and_back_without_recompute() {
        let run = |ladder: bool| {
            let mut hcfg = HarvestConfig::for_node(2);
            if ladder {
                hcfg.demote_to_host = true;
                hcfg.compress_before_demote = true;
            }
            let node = SimNode::new(NodeSpec::h100x2().with_ssd(256 * GIB));
            let mut hr = HarvestRuntime::new(node, hcfg);
            let kv_cfg = KvConfig {
                model: find_kv_model("deepseek").unwrap(),
                block_tokens: 16,
                local_capacity_blocks: 4,
                use_harvest: true,
                host_backed_peer: false, // lossy: only the ladder saves them
            };
            let mut kv = KvOffloadManager::new(kv_cfg, 0);
            let s = SeqId(1);
            for _ in 0..16 * 12 {
                kv.append_token(&mut hr, s); // 12 blocks vs 4 slots: spills to peer
            }
            assert!(kv.stats.evictions_to_peer > 0, "spill to peer expected");
            // Guaranteed batch tenant bursts to the whole peer GPU:
            // nothing short of displacing every harvest lease satisfies it.
            let mut fleet = TenantFleet::new();
            fleet.push(Box::new(BatchActor::new(
                "batch-0",
                1,
                80 * GIB,
                2_000_000,
                2_000_000,
                TenantPriority::Guaranteed,
                3,
            )));
            for t in 1..=5u64 {
                let now = hr.node.clock.now();
                fleet.advance_to(&mut hr, now.max(t * 2_000_000));
            }
            kv.sync(&mut hr);
            (hr, kv)
        };

        // -- ladder on: compress -> demote -> SSD write-back -> home, no
        //    recompute.
        let (mut hr, mut kv) = run(true);
        assert!(kv.stats.compressions > 0, "pressure must compress before demoting");
        assert!(kv.stats.demotions > 0, "full burst must still demote");
        assert_eq!(kv.stats.recomputes, 0, "ladder keeps every block alive");
        assert!(kv.compressed_blocks().count() > 0, "tags survive demotion");
        // idle out the demoted blocks; compressed host residents page out
        // to the SSD arena
        let now = hr.node.clock.now();
        hr.advance_to(now + 100_000_000);
        let stepped = kv.age_idle_blocks(&mut hr, 1_000_000, 50);
        assert!(stepped > 0, "aging sweep must move idle blocks");
        assert!(
            hr.live_bytes_on_tier(MemoryTier::Ssd) > 0,
            "compressed idle blocks write back to SSD"
        );
        assert_eq!(
            hr.pager().mapped_bytes(),
            hr.node.ssd.used(),
            "pager page table covers the SSD arena exactly"
        );
        // decode touches the sequence again: everything comes home
        kv.access_seq(&mut hr, s);
        assert_eq!(kv.stats.recomputes, 0, "round trip completes with zero recomputes");
        assert!(kv.stats.ssd_reloads > 0, "blocks reloaded from the SSD tier");
        assert!(kv.stats.bytes_from_ssd > 0);
        assert!(kv.stats.decompress_ns > 0, "reload pays the modeled decompression cost");
        kv.check_invariants().unwrap();

        // -- ladder off: the same burst drops lossy leases and decode
        //    pays recomputes.
        let (mut hr, mut kv) = run(false);
        assert_eq!(kv.stats.compressions, 0);
        assert_eq!(kv.stats.demotions, 0);
        kv.access_seq(&mut hr, s);
        assert!(
            kv.stats.recomputes > 0,
            "without the ladder, displaced lossy blocks must be recomputed"
        );
        kv.check_invariants().unwrap();
    }
}
