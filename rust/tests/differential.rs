//! Differential equivalence: one stepper, proven diverge-proof.
//!
//! The serving loop body exists exactly once (`server/stepper.rs`);
//! `SimEngine::run` drives it to completion and a 1-node `Cluster::run`
//! drives it through the event calendar. These tests pin the two paths
//! to *bit-for-bit* identical results — per-request completion times,
//! KV counter ledgers (reloads, recomputes, promotions, ...), and
//! per-tier byte ledgers — across router policies, schedulers, shared
//! prefixes, co-tenant fleets, prefetch, idle-aging, and the SLO
//! admission controller (admit/defer/shed decisions and shed ledgers
//! must match id-for-id).
//!
//! Also here: same-seed determinism of the calendar path, and a golden
//! trace for one canonical 4-node workload so stepper edits that shift
//! event ordering fail loudly. The golden file blesses itself on first
//! run (it is committed as `{"unblessed": true}` because goldens cannot
//! be hand-computed); once blessed, any drift is a hard failure.

use harvest::cluster::{Cluster, ClusterReport, ClusterSpec, RouterPolicy, SchedulerSpec, TierLedger};
use harvest::control::{AdmissionConfig, SloConfig};
use harvest::harvest::{HarvestConfig, HarvestRuntime, PrefetchConfig};
use harvest::kv::{KvConfig, KvStats, SeqId};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::server::{
    AgingConfig, RequestOutcome, SimEngine, SimEngineConfig, WorkloadGen, WorkloadSpec,
};
use harvest::tenantsim::{TenantFleet, TenantMix};
use harvest::util::json::{obj, Json};

fn kv_cfg(cap_blocks: usize) -> KvConfig {
    KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: cap_blocks,
        use_harvest: true,
        host_backed_peer: false,
    }
}

fn tenant_mix() -> TenantMix {
    TenantMix { enabled: true, training: 1, inference: 1, batch: 1, ..Default::default() }
}

/// Everything the two paths must agree on, bit for bit.
#[derive(Debug, PartialEq)]
struct Trace {
    completions: Vec<RequestOutcome>,
    sheds: Vec<SeqId>,
    kv_stats: KvStats,
    ledger: TierLedger,
    steps: u64,
    prefix_hits: u64,
    decode_stall_ns: u64,
    tokens_generated: u64,
    deferred_admissions: u64,
}

fn sim_side(
    engine: SimEngineConfig,
    sched: SchedulerSpec,
    spec: WorkloadSpec,
    mix: Option<&TenantMix>,
) -> Trace {
    let node = NodeSpec::h100x2();
    let n_gpus = node.gpus.len();
    let hbm = node.gpus.first().map(|g| g.hbm_bytes).unwrap_or(0);
    let mut hr = HarvestRuntime::new(SimNode::new(node), HarvestConfig::for_node(2));
    let mut eng = SimEngine::new(engine, sched.build(), 0);
    if let Some(m) = mix {
        // Mirror `Cluster::new` exactly: node 0's fleet is salted with
        // its node id (0) and dropped when empty.
        let fleet = TenantFleet::from_mix(m, n_gpus, hbm, 0);
        if !fleet.is_empty() {
            eng = eng.with_tenants(fleet);
        }
    }
    let report = eng.run(&mut hr, WorkloadGen::new(spec).generate());
    Trace {
        completions: report.completions,
        sheds: report.sheds,
        kv_stats: report.kv_stats,
        ledger: TierLedger::snapshot(&hr),
        steps: report.steps,
        prefix_hits: eng.stepper().prefix_hits(),
        decode_stall_ns: report.metrics.decode_stall_ns,
        tokens_generated: report.metrics.tokens_generated,
        deferred_admissions: report.metrics.deferred_admissions,
    }
}

fn cluster_side(
    engine: SimEngineConfig,
    sched: SchedulerSpec,
    spec: WorkloadSpec,
    policy: RouterPolicy,
    mix: Option<&TenantMix>,
) -> Trace {
    let mut cspec = ClusterSpec::new(1);
    cspec.router = policy;
    cspec.tenants = mix.cloned();
    let mut cluster = Cluster::new(&cspec, engine, sched);
    let report = cluster.run(WorkloadGen::new(spec).generate());
    assert_eq!(report.stats.shed, 0, "1-node default spec must not shed at the router");
    let n = &report.per_node[0];
    Trace {
        completions: n.completions.clone(),
        sheds: cluster.node(0).shed_ids().to_vec(),
        kv_stats: n.kv_stats.clone(),
        ledger: n.ledger,
        steps: n.steps,
        prefix_hits: n.prefix_hits,
        decode_stall_ns: n.metrics.decode_stall_ns,
        tokens_generated: n.metrics.tokens_generated,
        deferred_admissions: n.metrics.deferred_admissions,
    }
}

fn assert_equivalent(
    label: &str,
    engine: SimEngineConfig,
    sched: SchedulerSpec,
    spec: WorkloadSpec,
    policy: RouterPolicy,
    mix: Option<&TenantMix>,
) {
    let sim = sim_side(engine, sched, spec, mix);
    let cluster = cluster_side(engine, sched, spec, policy, mix);
    assert!(
        !sim.completions.is_empty(),
        "{label}: the case must actually serve requests"
    );
    assert_eq!(sim, cluster, "{label}: single-node cluster diverged from the bare engine");
}

fn burst_workload() -> WorkloadSpec {
    WorkloadSpec {
        n_requests: 20,
        mean_prompt_tokens: 48.0,
        max_new_tokens: 6,
        mean_interarrival_ns: 0,
        seed: 7,
        ..Default::default()
    }
}

fn staggered_prefix_workload() -> WorkloadSpec {
    WorkloadSpec {
        n_requests: 24,
        mean_prompt_tokens: 64.0,
        max_new_tokens: 8,
        mean_interarrival_ns: 1_000_000,
        shared_prefix_fraction: 0.7,
        shared_prefix_tokens: 32,
        n_prefix_groups: 3,
        seed: 11,
        ..Default::default()
    }
}

/// The satellite matrix: every router policy × both schedulers × both
/// workload shapes, under memory pressure (tight pool → real harvest
/// traffic on both paths).
#[test]
fn one_node_cluster_matches_engine_across_policies_and_schedulers() {
    let engine = SimEngineConfig::new(kv_cfg(48), 4, 12);
    for policy in
        [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::PrefixAffinity]
    {
        for sched in [SchedulerSpec::Fcfs, SchedulerSpec::CompletelyFair { quantum: 1 }] {
            for (wname, spec) in
                [("burst", burst_workload()), ("staggered", staggered_prefix_workload())]
            {
                let label = format!("{:?}/{:?}/{wname}", policy, sched);
                assert_equivalent(&label, engine, sched, spec, policy, None);
            }
        }
    }
}

/// Co-tenant fleets ride the same time advances on both paths: the
/// fleet is installed at t=0 and stepped inside the stepper, so tenant
/// churn lands identically.
#[test]
fn one_node_cluster_matches_engine_with_tenants() {
    let engine = SimEngineConfig::new(kv_cfg(64), 4, 12);
    let mix = tenant_mix();
    assert_equivalent(
        "tenants/least-loaded/cf",
        engine,
        SchedulerSpec::CompletelyFair { quantum: 1 },
        staggered_prefix_workload(),
        RouterPolicy::LeastLoaded,
        Some(&mix),
    );
    assert_equivalent(
        "tenants/round-robin/fcfs",
        engine,
        SchedulerSpec::Fcfs,
        burst_workload(),
        RouterPolicy::RoundRobin,
        Some(&mix),
    );
}

/// Prefetch planning and host→peer promotion run inside the stepper —
/// the overlap window and deadlines match on both paths.
#[test]
fn one_node_cluster_matches_engine_with_prefetch() {
    let engine =
        SimEngineConfig::new(kv_cfg(60), 8, 16).with_prefetch(PrefetchConfig::default());
    assert_equivalent(
        "prefetch/least-loaded/cf",
        engine,
        SchedulerSpec::CompletelyFair { quantum: 1 },
        burst_workload(),
        RouterPolicy::LeastLoaded,
        None,
    );
}

/// The idle-aging ladder ticks at the stepper's cadence — previously it
/// was wired into *neither* loop (only the `tier_ladder` bench drove it
/// by hand), so the two paths could never even agree on when blocks
/// age. Now the cadence is part of the engine config.
#[test]
fn one_node_cluster_matches_engine_with_idle_aging() {
    let engine = SimEngineConfig::new(kv_cfg(48), 4, 12).with_aging(AgingConfig::default());
    assert_equivalent(
        "aging/least-loaded/fcfs",
        engine,
        SchedulerSpec::Fcfs,
        staggered_prefix_workload(),
        RouterPolicy::LeastLoaded,
        None,
    );
}

/// The SLO admission controller is part of the shared loop body, so a
/// controller-armed engine and a 1-node cluster make identical
/// admit/defer/shed calls: completions, shed ledgers (exact ids, in
/// shed order), and deferral counters all match bit for bit. The
/// cluster side relies on `Cluster::new` passing a pre-armed engine
/// config through untouched under the default static spec.
#[test]
fn one_node_cluster_matches_engine_with_admission_controller() {
    let acfg = AdmissionConfig {
        slo: SloConfig {
            ttft_p99_ns: 5_000_000,
            goodput_floor_tps: 0.0,
            window_ns: 10_000_000,
        },
        high_watermark_pct: 85,
        low_watermark_pct: 60,
    };
    // Tight pool + sustained overload: the controller must actually
    // defer and shed on both paths, or the arm proves nothing (guarded
    // below).
    let engine = SimEngineConfig::new(kv_cfg(32), 2, 4).with_admission(acfg);
    let overload = WorkloadSpec {
        n_requests: 48,
        mean_prompt_tokens: 128.0,
        max_new_tokens: 16,
        mean_interarrival_ns: 150_000,
        seed: 23,
        ..Default::default()
    };
    let sim = sim_side(engine, SchedulerSpec::Fcfs, overload, None);
    let cluster =
        cluster_side(engine, SchedulerSpec::Fcfs, overload, RouterPolicy::LeastLoaded, None);
    assert!(!sim.sheds.is_empty(), "controller arm: the case must actually shed");
    assert!(!sim.completions.is_empty(), "controller arm: the case must still serve");
    assert_eq!(
        sim, cluster,
        "controller-on single-node cluster diverged from the bare engine"
    );
}

// ---------------------------------------------------------------------
// Determinism + golden trace (calendar path)
// ---------------------------------------------------------------------

fn canonical_4node() -> (ClusterSpec, SimEngineConfig, SchedulerSpec, WorkloadSpec) {
    let mut spec = ClusterSpec::new(4);
    spec.router = RouterPolicy::PrefixAffinity;
    spec.spill_queue_depth = 2;
    spec.tenants = Some(tenant_mix());
    let engine = SimEngineConfig::new(kv_cfg(48), 4, 8).with_aging(AgingConfig::default());
    let sched = SchedulerSpec::CompletelyFair { quantum: 1 };
    let workload = WorkloadSpec {
        n_requests: 32,
        mean_prompt_tokens: 64.0,
        max_new_tokens: 8,
        mean_interarrival_ns: 500_000,
        shared_prefix_fraction: 0.6,
        shared_prefix_tokens: 32,
        n_prefix_groups: 4,
        seed: 42,
        ..Default::default()
    };
    (spec, engine, sched, workload)
}

fn run_canonical() -> (ClusterReport, Vec<harvest::cluster::Dispatch>) {
    let (spec, engine, sched, workload) = canonical_4node();
    let mut cluster = Cluster::new(&spec, engine, sched);
    let report = cluster.run(WorkloadGen::new(workload).generate());
    (report, cluster.dispatch_log().to_vec())
}

/// Integer-only summary of a cluster run — stable across platforms, and
/// sensitive to any shift in event ordering (completion times fold into
/// a running hash).
fn summarize(report: &ClusterReport) -> Json {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for n in &report.per_node {
        for c in &n.completions {
            for v in [c.id.0, c.arrival, c.first_token_at, c.finished_at, c.generated as u64] {
                hash ^= v;
                hash = hash.wrapping_mul(0x1000_0000_01b3);
            }
        }
    }
    let nodes: Vec<Json> = report
        .per_node
        .iter()
        .map(|n| {
            obj([
                ("node", Json::from(n.node)),
                ("routed", Json::from(n.routed)),
                ("finished", Json::from(n.finished)),
                ("steps", Json::from(n.steps)),
                ("prefix_hits", Json::from(n.prefix_hits)),
                ("reloads", Json::from(n.kv_stats.reloads())),
                ("recomputes", Json::from(n.kv_stats.recomputes)),
                ("ledger_peer", Json::from(n.ledger.peer)),
                ("ledger_cxl", Json::from(n.ledger.cxl)),
                ("ledger_host", Json::from(n.ledger.host)),
                ("ledger_ssd", Json::from(n.ledger.ssd)),
            ])
        })
        .collect();
    obj([
        ("requests_finished", Json::from(report.aggregate.requests_finished)),
        ("tokens_generated", Json::from(report.aggregate.tokens_generated)),
        ("makespan_ns", Json::from(report.aggregate.makespan_ns())),
        ("routed", Json::from(report.stats.routed)),
        ("shed", Json::from(report.stats.shed)),
        ("prefix_migrations", Json::from(report.stats.prefix_migrations)),
        ("migrated_bytes", Json::from(report.stats.migrated_bytes)),
        ("fabric_bytes", Json::from(report.fabric_bytes)),
        // Masked to 53 bits: util::json stores numbers as f64, and we
        // want the golden file integer-exact.
        ("completion_hash", Json::from(hash & ((1u64 << 53) - 1))),
        ("per_node", Json::Arr(nodes)),
    ])
}

/// Same seed → identical `ClusterReport`, twice over, including the
/// calendar's full dispatch order.
#[test]
fn same_seed_same_report() {
    let (a, da) = run_canonical();
    let (b, db) = run_canonical();
    assert_eq!(summarize(&a).to_string(), summarize(&b).to_string());
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(da, db, "dispatch order must be deterministic");
    for (x, y) in a.per_node.iter().zip(&b.per_node) {
        assert_eq!(x.completions, y.completions);
        assert_eq!(x.kv_stats, y.kv_stats);
    }
}

/// Golden trace for the canonical 4-node workload. Committed unblessed
/// (`{"unblessed": true}`); the first test run regenerates and blesses
/// it in the working tree, after which any event-ordering drift fails
/// against the blessed copy. Re-bless deliberately by resetting the
/// file to `{"unblessed": true}`.
#[test]
fn golden_trace_4node() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/cluster_4node.json");
    let (report, _) = run_canonical();
    let got = summarize(&report).to_string();
    let committed = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("golden file missing at {path}: {e}"));
    if committed.contains("unblessed") {
        std::fs::write(path, &got).expect("bless golden file");
        return;
    }
    assert_eq!(
        committed.trim(),
        got,
        "canonical 4-node trace drifted — if the change is intentional, reset \
         {path} to {{\"unblessed\": true}} and re-run to re-bless"
    );
}
