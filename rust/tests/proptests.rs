//! Randomized property tests over the system's core invariants
//! (DESIGN.md §Repository layout lists them). Uses the crate's own
//! `util::check` harness (proptest is not vendored); every failure
//! prints a reproducing seed.

use harvest::harvest::{
    AllocHints, HarvestConfig, HarvestRuntime, Lease, MemoryTier, PayloadKind, PrefetchConfig,
    RevocationAction, RevocationReason, TierPreference, Transfer, VictimPolicy,
};
use harvest::kv::{BlockResidency, KvConfig, KvOffloadManager, SeqId};
use harvest::memsim::{DeviceId, FitStrategy, Hbm, NodeSpec, SimNode, TenantLoad};
use harvest::moe::{find_kv_model, find_moe_model, ExpertRebalancer, RouterSim};
use harvest::server::{CompletelyFair, Fcfs, Scheduler, WorkloadGen, WorkloadSpec};
use harvest::util::check;
use harvest::util::rng::Rng;
use std::collections::BTreeMap;

const MIB: u64 = 1 << 20;
const GIB: u64 = 1 << 30;

fn err(msg: String) -> Result<(), String> {
    Err(msg)
}

// ---------------------------------------------------------------------
// HBM allocator
// ---------------------------------------------------------------------

/// Random alloc/free interleavings: accounting identity, no overlapping
/// live segments, no double allocation, full coalescing on empty.
#[test]
fn prop_hbm_allocator_soundness() {
    check("hbm-soundness", 200, 0x48424D, |rng| {
        let strategy = match rng.below(3) {
            0 => FitStrategy::BestFit,
            1 => FitStrategy::FirstFit,
            _ => FitStrategy::WorstFit,
        };
        let cap = (1 + rng.below(64)) * 16 * MIB;
        let mut hbm = Hbm::new(cap, strategy);
        let mut live: Vec<(harvest::memsim::AllocId, u64)> = Vec::new();
        for _ in 0..rng.below(200) + 20 {
            if live.is_empty() || rng.bool(0.6) {
                let size = (1 + rng.below(32)) * MIB;
                if let Ok(id) = hbm.alloc(size) {
                    if live.iter().any(|&(l, _)| l == id) {
                        return err(format!("AllocId {id:?} reused while live"));
                    }
                    live.push((id, size));
                }
            } else {
                let i = rng.below(live.len() as u64) as usize;
                let (id, size) = live.swap_remove(i);
                let freed = hbm.free(id);
                if freed != size {
                    return err(format!("freed {freed} != allocated {size}"));
                }
            }
            // Accounting identity.
            let used: u64 = live.iter().map(|&(_, s)| s).sum();
            if hbm.used() != used {
                return err(format!("used {} != live sum {used}", hbm.used()));
            }
            if hbm.used() + hbm.free_bytes() != cap {
                return err("used + free != capacity".into());
            }
            // No overlapping live segments.
            let mut segs: Vec<(u64, u64)> = live
                .iter()
                .map(|&(id, s)| (hbm.offset_of(id).expect("live alloc has offset"), s))
                .collect();
            segs.sort();
            for w in segs.windows(2) {
                if w[0].0 + w[0].1 > w[1].0 {
                    return err(format!("overlap: {:?} then {:?}", w[0], w[1]));
                }
            }
        }
        // Free everything: allocator must coalesce back to one segment.
        for (id, _) in live.drain(..) {
            hbm.free(id);
        }
        if hbm.used() != 0 || hbm.largest_free() != cap {
            return err(format!(
                "after full free: used={} largest_free={} cap={cap}",
                hbm.used(),
                hbm.largest_free()
            ));
        }
        Ok(())
    });
}

/// Fragmented arenas still satisfy any request <= largest_free, and
/// fragmentation() stays in [0,1].
#[test]
fn prop_hbm_largest_free_is_honest() {
    check("hbm-largest-free", 120, 0xF4A6, |rng| {
        let mut hbm = Hbm::new(256 * MIB, FitStrategy::BestFit);
        let mut live = Vec::new();
        for _ in 0..40 {
            if let Ok(id) = hbm.alloc((1 + rng.below(16)) * MIB) {
                live.push(id);
            }
        }
        // free a random subset to fragment
        live.retain(|&id| {
            if rng.bool(0.5) {
                hbm.free(id);
                false
            } else {
                true
            }
        });
        let f = hbm.fragmentation();
        if !(0.0..=1.0).contains(&f) {
            return err(format!("fragmentation {f} out of range"));
        }
        let lf = hbm.largest_free();
        if lf > 0 && hbm.alloc(lf).is_err() {
            return err(format!("alloc(largest_free={lf}) failed"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Harvest controller
// ---------------------------------------------------------------------

/// Random alloc/alloc_many/release/revoke/pressure interleavings under
/// the session API: every revocation is observed exactly once via
/// `drain_revocations` (releases never produce events), live accounting
/// matches the arena, and pressure enforcement converges to budget. No
/// shared state between the runtime and this "consumer" — the whole
/// point of the pull model.
#[test]
fn prop_session_events_exactly_once() {
    check("session-events-once", 80, 0xCB01, |rng| {
        let n_gpus = 2 + rng.below(3) as usize;
        let node = SimNode::new(NodeSpec::nvlink_domain(n_gpus));
        let mut cfg = HarvestConfig::for_node(n_gpus);
        cfg.victim_policy = match rng.below(4) {
            0 => VictimPolicy::Lifo,
            1 => VictimPolicy::Fifo,
            2 => VictimPolicy::LargestFirst,
            _ => VictimPolicy::SmallestFirst,
        };
        let mut hr = HarvestRuntime::new(node, cfg);
        let session = hr.open_session(PayloadKind::Generic);
        let mut live: Vec<Lease> = Vec::new();
        let mut released: Vec<u64> = Vec::new();
        let mut seen: BTreeMap<u64, u32> = BTreeMap::new();
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        for step in 0..rng.below(120) + 20 {
            match rng.below(10) {
                0..=3 => {
                    if let Ok(l) = session.alloc(
                        &mut hr,
                        (1 + rng.below(512)) * MIB,
                        TierPreference::PEER_ONLY,
                        hints,
                    ) {
                        if rng.bool(0.3) {
                            Transfer::new()
                                .populate(&l, DeviceId::Host)
                                .submit(&mut hr)
                                .map_err(|e| format!("populate: {e}"))?;
                        }
                        live.push(l);
                    }
                }
                4 => {
                    // vectored batch: all-or-nothing
                    let sizes: Vec<u64> =
                        (0..1 + rng.below(4)).map(|_| (1 + rng.below(256)) * MIB).collect();
                    let before: u64 = (0..n_gpus).map(|p| hr.live_bytes_on(p)).sum();
                    match session.alloc_many(&mut hr, &sizes, TierPreference::PEER_ONLY, hints)
                    {
                        Ok(batch) => {
                            let peer = batch[0].tier();
                            if !batch.iter().all(|l| l.tier() == peer) {
                                return err("alloc_many split across peers".into());
                            }
                            live.extend(batch);
                        }
                        Err(_) => {
                            let after: u64 = (0..n_gpus).map(|p| hr.live_bytes_on(p)).sum();
                            if after != before {
                                return err(format!(
                                    "failed alloc_many changed accounting {before} -> {after}"
                                ));
                            }
                        }
                    }
                }
                5..=6 => {
                    if !live.is_empty() {
                        let l = live.swap_remove(rng.below(live.len() as u64) as usize);
                        let id = l.id().0;
                        session.release(&mut hr, l).map_err(|e| format!("release: {e}"))?;
                        released.push(id);
                    }
                }
                7..=8 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let id = live[i].id();
                        hr.revoke(id, RevocationReason::PolicyEviction);
                        // the stale RAII owner stays in `live` until the
                        // event is drained below — like a real consumer
                    }
                }
                _ => {
                    // tenant pressure spike on a random peer
                    let peer = 1 + rng.below((n_gpus - 1) as u64) as usize;
                    let now = hr.node.clock.now();
                    let used = rng.below(80) * GIB;
                    hr.node.set_tenant_load(
                        peer,
                        TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + step + 1, used)]),
                    );
                    hr.advance_to(now + step + 2);
                }
            }
            // tick boundary: observe events, drop stale owners
            for ev in session.drain_revocations(&mut hr) {
                *seen.entry(ev.lease.0).or_insert(0) += 1;
                live.retain(|l| l.id() != ev.lease);
            }
            // invariant: our arena usage equals live lease accounting
            for p in 0..n_gpus {
                let arena = hr.node.gpus[p].hbm.used();
                let leases = hr.live_bytes_on(p);
                if arena != leases {
                    return err(format!("gpu{p}: arena {arena} != leases {leases}"));
                }
            }
        }
        // Shutdown: revoke all peers; drain the tail.
        for p in 0..n_gpus {
            hr.revoke_peer(p, RevocationReason::Shutdown);
        }
        for ev in session.drain_revocations(&mut hr) {
            *seen.entry(ev.lease.0).or_insert(0) += 1;
            live.retain(|l| l.id() != ev.lease);
        }
        if !live.is_empty() {
            return err(format!("{} leases alive after shutdown", live.len()));
        }
        for (&id, &count) in &seen {
            if count != 1 {
                return err(format!("lease {id} observed {count} times"));
            }
            if released.contains(&id) {
                return err(format!("released lease {id} produced an event"));
            }
        }
        // Every recorded revocation must have been observed exactly once.
        for rev in &hr.revocations {
            if seen.get(&rev.handle.id.0) != Some(&1) {
                return err(format!("revocation {:?} not observed once", rev.handle.id));
            }
        }
        Ok(())
    });
}

/// Leases dropped without an explicit release never leak accounting:
/// at every step arena usage equals the `bytes_on` ledger, and after the
/// final sweep both return to zero — no matter how drops, releases,
/// revocations and sweeps interleave.
#[test]
fn prop_leases_never_leak_accounting() {
    check("lease-leak-sweep", 80, 0x1EAB, |rng| {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        let session = hr.open_session(PayloadKind::Generic);
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let mut held: Vec<Lease> = Vec::new();
        let mut dropped = 0u64;
        for _ in 0..rng.below(150) + 20 {
            match rng.below(8) {
                0..=3 => {
                    if let Ok(l) = session.alloc(
                        &mut hr,
                        (1 + rng.below(256)) * MIB,
                        TierPreference::PEER_ONLY,
                        hints,
                    ) {
                        held.push(l);
                    }
                }
                4 => {
                    // leak: drop the RAII owner without releasing
                    if !held.is_empty() {
                        let l = held.swap_remove(rng.below(held.len() as u64) as usize);
                        drop(l);
                        dropped += 1;
                    }
                }
                5 => {
                    if !held.is_empty() {
                        let l = held.swap_remove(rng.below(held.len() as u64) as usize);
                        session.release(&mut hr, l).map_err(|e| format!("release: {e}"))?;
                    }
                }
                6 => {
                    if !held.is_empty() {
                        let id = held[rng.below(held.len() as u64) as usize].id();
                        hr.revoke(id, RevocationReason::PolicyEviction);
                        for ev in session.drain_revocations(&mut hr) {
                            held.retain(|l| l.id() != ev.lease);
                        }
                    }
                }
                _ => {
                    hr.sweep_leaked();
                }
            }
            // Leaked-but-unswept leases are still live and accounted, so
            // this identity must hold at *every* step:
            for p in 0..2 {
                let arena = hr.node.gpus[p].hbm.used();
                let ledger = hr.live_bytes_on(p);
                if arena != ledger {
                    return err(format!("gpu{p}: arena {arena} != ledger {ledger}"));
                }
            }
        }
        // Drop everything still held and sweep: accounting returns to
        // zero — leaked leases are reclaimed, not lost.
        held.clear();
        hr.sweep_leaked();
        for p in 0..2 {
            if hr.live_bytes_on(p) != 0 || hr.node.gpus[p].hbm.used() != 0 {
                return err(format!(
                    "gpu{p}: {} bytes leaked after final sweep (dropped {dropped} leases)",
                    hr.live_bytes_on(p)
                ));
            }
        }
        Ok(())
    });
}

/// After `enforce_pressure`, every peer's harvested bytes fit within
/// capacity - tenant - reserve (and the MIG limit if set).
#[test]
fn prop_pressure_enforcement_converges() {
    check("pressure-converges", 100, 0x9E55, |rng| {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut cfg = HarvestConfig::for_node(2);
        cfg.reserve_bytes = rng.below(8) * GIB;
        let reserve = cfg.reserve_bytes;
        let mut hr = HarvestRuntime::new(node, cfg);
        let session = hr.open_session(PayloadKind::Generic);
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let mut held: Vec<Lease> = Vec::new();
        for _ in 0..rng.below(20) + 1 {
            if let Ok(l) = session.alloc(
                &mut hr,
                (1 + rng.below(8)) * GIB,
                TierPreference::PEER_ONLY,
                hints,
            ) {
                held.push(l);
            }
        }
        let tenant_used = rng.below(80) * GIB;
        let now = hr.node.clock.now();
        hr.node.set_tenant_load(
            1,
            TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1, tenant_used)]),
        );
        hr.advance_to(now + 2);
        let budget = (80 * GIB).saturating_sub(tenant_used).saturating_sub(reserve);
        let ours = hr.live_bytes_on(1);
        if ours > budget {
            return err(format!("after enforcement: ours {ours} > budget {budget}"));
        }
        drop(held);
        hr.sweep_leaked();
        Ok(())
    });
}

// ---------------------------------------------------------------------
// KV manager + block table
// ---------------------------------------------------------------------

fn kv_cfg(rng: &mut Rng, use_harvest: bool) -> KvConfig {
    KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 8 + 8 * rng.below(3) as u32,
        local_capacity_blocks: 8 + rng.below(64) as usize,
        use_harvest,
        host_backed_peer: rng.bool(0.3),
    }
}

/// Random append/access/evict/finish interleavings with tenant pressure:
/// the unified block table never violates its invariants, the local pool
/// never exceeds capacity, and finished sequences release everything.
#[test]
fn prop_kv_manager_invariants() {
    check("kv-invariants", 60, 0x4B56, |rng| {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        let use_harvest = rng.bool(0.7);
        let cfg = kv_cfg(rng, use_harvest);
        let cap = cfg.local_capacity_blocks;
        let mut kv = KvOffloadManager::new(cfg, 0);
        let mut seqs: Vec<SeqId> = Vec::new();
        let mut next_seq = 0u64;
        for _ in 0..rng.below(300) + 50 {
            match rng.below(10) {
                0..=5 => {
                    let seq = if seqs.is_empty() || rng.bool(0.2) {
                        let s = SeqId(next_seq);
                        next_seq += 1;
                        seqs.push(s);
                        s
                    } else {
                        seqs[rng.below(seqs.len() as u64) as usize]
                    };
                    kv.append_token(&mut hr, seq);
                }
                6..=7 => {
                    if !seqs.is_empty() {
                        let seq = seqs[rng.below(seqs.len() as u64) as usize];
                        kv.access_seq(&mut hr, seq);
                    }
                }
                8 => {
                    if !seqs.is_empty() {
                        let i = rng.below(seqs.len() as u64) as usize;
                        let seq = seqs.swap_remove(i);
                        kv.finish_seq(&mut hr, seq);
                        if !kv.table().seq_blocks(seq).is_empty() {
                            return err(format!("{seq:?} finished but still has blocks"));
                        }
                    }
                }
                _ => {
                    // pressure spike revokes peer-resident blocks
                    let now = hr.node.clock.now();
                    let used = rng.below(80) * GIB;
                    hr.node.set_tenant_load(
                        1,
                        TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + 1, used)]),
                    );
                    hr.advance_to(now + 2);
                }
            }
            kv.check_invariants().map_err(|e| format!("kv invariant: {e}"))?;
            kv.table().check_invariants().map_err(|e| format!("table invariant: {e}"))?;
            if kv.local_blocks() > cap {
                return err(format!("local blocks {} > capacity {cap}", kv.local_blocks()));
            }
        }
        Ok(())
    });
}

/// The prefetch plan/submit race: a revocation (targeted, peer-wide, or
/// tenant-pressure-driven) arriving *between* `plan_prefetch` and
/// `submit_prefetch` must never produce a stale-lease read — submit
/// revalidates every entry, issues only still-valid reloads, and all
/// manager/table invariants hold throughout. Late-used and never-used
/// prefetches are accounted, not corrupted.
#[test]
fn prop_prefetch_plan_submit_revocation_race() {
    check("prefetch-race", 50, 0x9F31, |rng| {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        let cfg = KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: 6 + rng.below(10) as usize,
            use_harvest: true,
            host_backed_peer: rng.bool(0.3),
        };
        let mut kv = KvOffloadManager::new(cfg, 0).with_prefetch(PrefetchConfig::default());
        let seqs: Vec<SeqId> = (0u64..3).map(SeqId).collect();
        for &s in &seqs {
            for _ in 0..(16 * (2 + rng.below(4))) {
                kv.append_token(&mut hr, s);
            }
        }
        for round in 0..rng.below(25) + 5 {
            let plan = kv.plan_prefetch(&mut hr, &seqs);
            // The race: revocations land after the plan snapshot.
            if rng.bool(0.7) {
                match rng.below(3) {
                    0 => {
                        hr.revoke_peer(1, RevocationReason::ExternalReclaim);
                    }
                    1 => {
                        let ids: Vec<_> = hr.live_handles().map(|h| h.id).collect();
                        if !ids.is_empty() {
                            let id = ids[rng.below(ids.len() as u64) as usize];
                            hr.revoke(id, RevocationReason::PolicyEviction);
                        }
                    }
                    _ => {
                        let now = hr.node.clock.now();
                        hr.node.set_tenant_load(
                            1,
                            TenantLoad::from_steps(
                                80 * GIB,
                                vec![(0, 0), (now + round + 1, rng.below(81) * GIB)],
                            ),
                        );
                        hr.advance_to(now + round + 2);
                    }
                }
            }
            let deadline = hr.node.clock.now() + 1_000_000 + rng.below(5_000_000);
            kv.submit_prefetch(&mut hr, &plan, deadline);
            kv.check_invariants().map_err(|e| format!("post-submit: {e}"))?;
            // Consume some of it (hit or late), append more, or idle.
            match rng.below(3) {
                0 => {
                    let s = seqs[rng.below(3) as usize];
                    kv.access_seq(&mut hr, s);
                }
                1 => {
                    let s = seqs[rng.below(3) as usize];
                    kv.append_token(&mut hr, s);
                }
                _ => {
                    let now = hr.node.clock.now();
                    hr.advance_to(now + rng.below(2_000_000));
                }
            }
            kv.check_invariants().map_err(|e| format!("post-use: {e}"))?;
            // Ledger sanity: every issue resolves to at most one outcome.
            let pf = kv.prefetch_stats().unwrap();
            if pf.hits + pf.late + pf.wasted > pf.issued {
                return err(format!(
                    "outcomes exceed issues: {} + {} + {} > {}",
                    pf.hits, pf.late, pf.wasted, pf.issued
                ));
            }
        }
        Ok(())
    });
}

/// Without harvest, no block is ever peer-resident; with host_backed_peer,
/// eviction to peer keeps a host copy (never `Dropped` on revocation).
#[test]
fn prop_kv_tier_policy_respected() {
    check("kv-tier-policy", 60, 0x7137, |rng| {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        let cfg = KvConfig {
            model: find_kv_model("kimi").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: 8,
            use_harvest: false,
            host_backed_peer: false,
        };
        let mut kv = KvOffloadManager::new(cfg, 0);
        let s = SeqId(0);
        for _ in 0..rng.below(400) + 100 {
            kv.append_token(&mut hr, s);
        }
        let table = kv.table();
        for seq_block in table.seq_blocks(s) {
            if table.residency(*seq_block).map(|r| r.is_peer()).unwrap_or(false) {
                return err("harvest disabled but block on peer".into());
            }
        }
        Ok(())
    });
}

/// Random alloc/migrate/revoke/release/pressure sequences across tiers:
/// per-tier `bytes_on` accounting always equals both the arena usage and
/// the sum of live lease sizes by resident tier (so a lease can never be
/// accounted on two tiers at once), demotions update the surviving
/// lease's tier in place, and everything returns to zero at the end.
#[test]
fn prop_tiered_lease_accounting_under_migration() {
    check("tier-accounting", 60, 0x71E4, |rng| {
        let node = SimNode::new(NodeSpec::h100x2().with_cxl(32 * GIB));
        let mut cfg = HarvestConfig::for_node(2);
        cfg.demote_to_host = rng.bool(0.5);
        let mut hr = HarvestRuntime::new(node, cfg);
        let session = hr.open_session(PayloadKind::Generic);
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let tiers = [MemoryTier::PeerHbm(1), MemoryTier::Host, MemoryTier::CxlMem];
        let mut held: Vec<Lease> = Vec::new();
        for step in 0..rng.below(150) + 30 {
            match rng.below(10) {
                0..=3 => {
                    let pref = match rng.below(4) {
                        0 => TierPreference::FastestAvailable,
                        1 => TierPreference::PEER_ONLY,
                        2 => TierPreference::Pinned(MemoryTier::Host),
                        _ => TierPreference::Pinned(MemoryTier::CxlMem),
                    };
                    let hints = AllocHints {
                        durability: if rng.bool(0.5) {
                            harvest::harvest::Durability::Lossy
                        } else {
                            harvest::harvest::Durability::HostBacked
                        },
                        ..hints
                    };
                    if let Ok(l) =
                        session.alloc(&mut hr, (1 + rng.below(128)) * MIB, pref, hints)
                    {
                        held.push(l);
                    }
                }
                4..=5 => {
                    // migrate a random live lease to a random tier (a
                    // full destination fails cleanly, changing nothing)
                    if !held.is_empty() {
                        let i = rng.below(held.len() as u64) as usize;
                        let to = tiers[rng.below(3) as usize];
                        let l = &held[i];
                        if Transfer::new().migrate(l, to).submit(&mut hr).is_ok()
                            && l.tier() != to
                        {
                            return err(format!(
                                "migrated lease reports {} not {to}",
                                l.tier()
                            ));
                        }
                    }
                }
                6 => {
                    if !held.is_empty() {
                        let l = held.swap_remove(rng.below(held.len() as u64) as usize);
                        session.release(&mut hr, l).map_err(|e| format!("release: {e}"))?;
                    }
                }
                7 => {
                    if !held.is_empty() {
                        let i = rng.below(held.len() as u64) as usize;
                        hr.revoke(held[i].id(), RevocationReason::PolicyEviction);
                    }
                }
                _ => {
                    // tenant pressure spike on the peer; with
                    // demote_to_host on, lossy leases demote instead
                    let now = hr.node.clock.now();
                    let used = rng.below(80) * GIB;
                    hr.node.set_tenant_load(
                        1,
                        TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + step + 1, used)]),
                    );
                    hr.advance_to(now + step + 2);
                }
            }
            // observe events: drops leave `held`; demotions must already
            // have re-tiered the surviving lease
            for ev in session.drain_revocations(&mut hr) {
                match ev.action {
                    RevocationAction::Dropped => held.retain(|l| l.id() != ev.lease),
                    RevocationAction::Demoted { to } => {
                        let Some(l) = held.iter().find(|l| l.id() == ev.lease) else {
                            return err(format!("demotion for unknown lease {:?}", ev.lease));
                        };
                        if l.tier() != to || hr.tier_of(ev.lease) != Some(to) {
                            return err(format!(
                                "demoted lease on {} but event says {to}",
                                l.tier()
                            ));
                        }
                    }
                    RevocationAction::Compressed { .. } => {
                        // `compress_before_demote` is off in this test, so
                        // the compression rung must never fire.
                        return err(format!(
                            "compression event with the ladder disabled: {:?}",
                            ev.lease
                        ));
                    }
                }
            }
            // the identity, per tier: arena usage == runtime ledger ==
            // sum of live leases resident there, plus (arena-side only)
            // migration sources whose in-flight copies still pin their
            // segments (freed at copy completion, never reused early)
            for &tier in &tiers {
                let ledger = hr.live_bytes_on_tier(tier);
                let pending = hr.pending_free_bytes_on_tier(tier);
                let arena = match tier {
                    MemoryTier::PeerHbm(g) => hr.node.gpus[g].hbm.used(),
                    MemoryTier::Host => hr.node.host.used(),
                    MemoryTier::CxlMem => hr.node.cxl.used(),
                    MemoryTier::Ssd => hr.node.ssd.used(),
                    MemoryTier::LocalHbm => 0,
                };
                let leases: u64 =
                    held.iter().filter(|l| l.tier() == tier).map(|l| l.size()).sum();
                if ledger + pending != arena || ledger != leases {
                    return err(format!(
                        "{tier}: ledger {ledger} + pending {pending} != arena {arena} \
                         (leases {leases})"
                    ));
                }
            }
            // and no lease is double-counted across tiers
            let total: u64 = tiers.iter().map(|&t| hr.live_bytes_on_tier(t)).sum();
            let held_total: u64 = held.iter().map(|l| l.size()).sum();
            if total != held_total {
                return err(format!("tier sum {total} != held sum {held_total}"));
            }
        }
        // teardown: everything releases back to zero on every tier
        for l in held.drain(..) {
            session.release(&mut hr, l).map_err(|e| format!("final release: {e}"))?;
        }
        hr.sweep_leaked();
        for &tier in &tiers {
            if hr.live_bytes_on_tier(tier) != 0 {
                return err(format!("{tier}: bytes left after teardown"));
            }
            // every release drained its lease tag, so no deferred
            // migration source outlives its lease
            let arena = match tier {
                MemoryTier::PeerHbm(g) => hr.node.gpus[g].hbm.used(),
                MemoryTier::Host => hr.node.host.used(),
                MemoryTier::CxlMem => hr.node.cxl.used(),
                MemoryTier::Ssd => hr.node.ssd.used(),
                MemoryTier::LocalHbm => 0,
            };
            if arena != 0 {
                return err(format!("{tier}: {arena} arena bytes left after teardown"));
            }
        }
        Ok(())
    });
}

/// The cold-tier ladder keeps the books: random alloc / migrate (now
/// including the SSD tier) / compress / decompress / revoke / pressure
/// interleavings with the compress-before-demote ladder armed. At every
/// step each lease is accounted on exactly one tier at its *current*
/// (possibly compressed) size, a compressed size never exceeds the
/// original, the cold-tier pager's page table exactly covers the SSD
/// arena, and a compress -> demote -> promote -> decompress round trip
/// restores the original byte count.
#[test]
fn prop_ladder_accounting() {
    check("ladder-accounting", 40, 0x1ADD, |rng| {
        let node = SimNode::new(NodeSpec::h100x2().with_cxl(32 * GIB).with_ssd(64 * GIB));
        let mut cfg = HarvestConfig::for_node(2);
        cfg.demote_to_host = true;
        cfg.compress_before_demote = true;
        cfg.compress_ratio_pct = 1 + rng.below(99) as u32;
        let ratio = cfg.compress_ratio_pct;
        let mut hr = HarvestRuntime::new(node, cfg);
        let session = hr.open_session(PayloadKind::Generic);
        let base_hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let tiers =
            [MemoryTier::PeerHbm(1), MemoryTier::Host, MemoryTier::CxlMem, MemoryTier::Ssd];
        // `Lease::size()` snapshots the original byte count; the live
        // (possibly compressed) size is derived from the runtime's
        // compression tag with the controller's exact formula.
        let current_size = |hr: &HarvestRuntime, l: &Lease| -> Result<u64, String> {
            match hr.compression_of(l.id()) {
                None => Ok(l.size()),
                Some(info) => {
                    if info.original_size != l.size() {
                        return Err(format!(
                            "compression records original {} but lease says {}",
                            info.original_size,
                            l.size()
                        ));
                    }
                    let c = (info.original_size * u64::from(info.ratio) / 100).max(1);
                    if c > info.original_size {
                        return Err(format!(
                            "compressed {c} > original {}",
                            info.original_size
                        ));
                    }
                    Ok(c)
                }
            }
        };
        let mut held: Vec<Lease> = Vec::new();
        for step in 0..rng.below(120) + 30 {
            match rng.below(12) {
                0..=3 => {
                    let pref = match rng.below(5) {
                        0 => TierPreference::FastestAvailable,
                        1 => TierPreference::PEER_ONLY,
                        2 => TierPreference::Pinned(MemoryTier::Host),
                        3 => TierPreference::Pinned(MemoryTier::CxlMem),
                        _ => TierPreference::Pinned(MemoryTier::Ssd),
                    };
                    let hints = AllocHints {
                        durability: if rng.bool(0.5) {
                            harvest::harvest::Durability::Lossy
                        } else {
                            harvest::harvest::Durability::HostBacked
                        },
                        ..base_hints
                    };
                    if let Ok(l) =
                        session.alloc(&mut hr, (1 + rng.below(128)) * MIB, pref, hints)
                    {
                        held.push(l);
                    }
                }
                4..=5 => {
                    if !held.is_empty() {
                        let i = rng.below(held.len() as u64) as usize;
                        let to = tiers[rng.below(4) as usize];
                        let l = &held[i];
                        if Transfer::new().migrate(l, to).submit(&mut hr).is_ok()
                            && l.tier() != to
                        {
                            return err(format!(
                                "migrated lease reports {} not {to}",
                                l.tier()
                            ));
                        }
                    }
                }
                6 => {
                    // compress in place (idempotent on a compressed lease)
                    if !held.is_empty() {
                        let i = rng.below(held.len() as u64) as usize;
                        Transfer::new()
                            .compress(&held[i], ratio)
                            .submit(&mut hr)
                            .map_err(|e| format!("compress: {e}"))?;
                        if hr.compression_of(held[i].id()).is_none() {
                            return err("compress left no tag".into());
                        }
                    }
                }
                7 => {
                    // decompress (no-op on an uncompressed lease; a full
                    // arena fails cleanly and changes nothing)
                    if !held.is_empty() {
                        let i = rng.below(held.len() as u64) as usize;
                        if Transfer::new().decompress(&held[i]).submit(&mut hr).is_ok()
                            && hr.compression_of(held[i].id()).is_some()
                        {
                            return err("decompress left the tag".into());
                        }
                    }
                }
                8 => {
                    if !held.is_empty() {
                        let l = held.swap_remove(rng.below(held.len() as u64) as usize);
                        session.release(&mut hr, l).map_err(|e| format!("release: {e}"))?;
                    }
                }
                9 => {
                    if !held.is_empty() {
                        let i = rng.below(held.len() as u64) as usize;
                        hr.revoke(held[i].id(), RevocationReason::PolicyEviction);
                    }
                }
                _ => {
                    // pressure spike: the armed ladder compresses, then
                    // demotes, then drops
                    let now = hr.node.clock.now();
                    let used = rng.below(80) * GIB;
                    hr.node.set_tenant_load(
                        1,
                        TenantLoad::from_steps(80 * GIB, vec![(0, 0), (now + step + 1, used)]),
                    );
                    hr.advance_to(now + step + 2);
                }
            }
            for ev in session.drain_revocations(&mut hr) {
                match ev.action {
                    RevocationAction::Dropped => held.retain(|l| l.id() != ev.lease),
                    RevocationAction::Demoted { to } => {
                        let Some(l) = held.iter().find(|l| l.id() == ev.lease) else {
                            return err(format!("demotion for unknown lease {:?}", ev.lease));
                        };
                        if l.tier() != to {
                            return err(format!(
                                "demoted lease on {} but event says {to}",
                                l.tier()
                            ));
                        }
                    }
                    RevocationAction::Compressed { ratio: r } => {
                        let Some(l) = held.iter().find(|l| l.id() == ev.lease) else {
                            return err(format!(
                                "compression for unknown lease {:?}",
                                ev.lease
                            ));
                        };
                        match hr.compression_of(l.id()) {
                            Some(info) if info.ratio == r => {}
                            other => {
                                return err(format!(
                                    "Compressed {{ ratio: {r} }} event but tag is {other:?}"
                                ))
                            }
                        }
                    }
                }
            }
            // each lease on exactly one tier, at its current size
            let mut total = 0u64;
            let mut held_total = 0u64;
            for l in &held {
                held_total += current_size(&hr, l)?;
            }
            for &tier in &tiers {
                let ledger = hr.live_bytes_on_tier(tier);
                let pending = hr.pending_free_bytes_on_tier(tier);
                let mut leases = 0u64;
                for l in held.iter().filter(|l| l.tier() == tier) {
                    leases += current_size(&hr, l)?;
                }
                if ledger != leases {
                    return err(format!("{tier}: ledger {ledger} != lease sum {leases}"));
                }
                total += ledger;
                match tier {
                    MemoryTier::Ssd => {
                        // pager invariant: page table == arena occupancy
                        // (logical bytes padded up to whole pages)
                        if !hr.pager().balances(&hr.node.ssd) {
                            return err(format!(
                                "pager maps {} bytes but SSD arena holds {}",
                                hr.pager().mapped_bytes(),
                                hr.node.ssd.used()
                            ));
                        }
                        if ledger + pending != hr.pager().logical_bytes() {
                            return err(format!(
                                "ssd: ledger {ledger} + pending {pending} != pager \
                                 logical {}",
                                hr.pager().logical_bytes()
                            ));
                        }
                    }
                    _ => {
                        let arena = match tier {
                            MemoryTier::PeerHbm(g) => hr.node.gpus[g].hbm.used(),
                            MemoryTier::Host => hr.node.host.used(),
                            MemoryTier::CxlMem => hr.node.cxl.used(),
                            _ => 0,
                        };
                        if ledger + pending != arena {
                            return err(format!(
                                "{tier}: ledger {ledger} + pending {pending} != arena \
                                 {arena}"
                            ));
                        }
                    }
                }
            }
            if total != held_total {
                return err(format!("tier sum {total} != held sum {held_total}"));
            }
        }
        // compress -> demote -> promote -> decompress round trip: the
        // compressed size survives every hop and decompression restores
        // exactly the original byte count.
        let now = hr.node.clock.now();
        hr.node.set_tenant_load(1, TenantLoad::from_steps(80 * GIB, vec![(0, 0)]));
        hr.advance_to(now + 1);
        let l = session
            .alloc(&mut hr, 64 * MIB, TierPreference::PEER_ONLY, base_hints)
            .map_err(|e| format!("round-trip alloc: {e}"))?;
        let original = l.size();
        Transfer::new()
            .compress(&l, ratio)
            .submit(&mut hr)
            .map_err(|e| format!("round-trip compress: {e}"))?;
        let compressed = current_size(&hr, &l)?;
        if compressed > original {
            return err(format!("compressed {compressed} > original {original}"));
        }
        for to in [MemoryTier::Host, MemoryTier::Ssd, MemoryTier::Host] {
            Transfer::new()
                .migrate(&l, to)
                .submit(&mut hr)
                .map_err(|e| format!("round-trip migrate to {to}: {e}"))?;
            if current_size(&hr, &l)? != compressed {
                return err(format!("migration to {to} changed the compressed size"));
            }
        }
        let before = hr.live_bytes_on_tier(MemoryTier::Host);
        Transfer::new()
            .decompress(&l)
            .submit(&mut hr)
            .map_err(|e| format!("round-trip decompress: {e}"))?;
        if hr.compression_of(l.id()).is_some() {
            return err("round-trip decompression left the tag".into());
        }
        let after = hr.live_bytes_on_tier(MemoryTier::Host);
        if after - before != original - compressed {
            return err(format!(
                "round trip restored {} bytes, expected {}",
                after - before,
                original - compressed
            ));
        }
        held.push(l);
        // teardown: every tier and the pager return to zero
        for l in held.drain(..) {
            session.release(&mut hr, l).map_err(|e| format!("final release: {e}"))?;
        }
        hr.sweep_leaked();
        for &tier in &tiers {
            if hr.live_bytes_on_tier(tier) != 0 {
                return err(format!("{tier}: bytes left after teardown"));
            }
        }
        if hr.pager().pages_mapped() != 0 || hr.node.ssd.used() != 0 {
            return err(format!(
                "SSD not empty after teardown: {} pages, {} arena bytes",
                hr.pager().pages_mapped(),
                hr.node.ssd.used()
            ));
        }
        Ok(())
    });
}

/// Closed-loop tenant actors + harvest consumer churn, random
/// interleavings: at every step each GPU arena decomposes exactly into
/// tenant-held + live harvest leases + pending migration sources (and
/// likewise the host arena); guaranteed tenants never OOM while a
/// revocable harvest lease exists on the tier; and replay-mode fleets
/// reproduce the exogenous-timeline pressure sequence bit-for-bit (see
/// also `tenantsim::fleet`'s unit test of the same identity).
#[test]
fn prop_tenant_conservation() {
    use harvest::tenantsim::{
        BatchActor, InferenceActor, TenantFleet, TenantPriority, TrainingActor,
    };
    check("tenant-conservation", 40, 0x7E4A, |rng| {
        let mut spec = NodeSpec::h100x2();
        spec.host_dram_bytes = 64 * GIB; // small enough to contend
        let mut cfg = HarvestConfig::for_node(2);
        cfg.demote_to_host = rng.bool(0.5);
        let mut hr = HarvestRuntime::new(SimNode::new(spec), cfg);
        let session = hr.open_session(PayloadKind::Generic);
        let hints = AllocHints { compute_gpu: Some(0), ..Default::default() };
        let mut fleet = TenantFleet::new();
        if rng.bool(0.7) {
            fleet.push(Box::new(TrainingActor::new(
                "train",
                vec![0, 1],
                (1 + rng.below(8)) * GIB,
                rng.below(4) * GIB,
                rng.below(4) * GIB,
                32 * MIB,
                500_000 + rng.below(1_000_000),
            )));
        }
        if rng.bool(0.7) {
            fleet.push(Box::new(InferenceActor::new(
                "infer",
                1,
                80 * GIB,
                0.05 + rng.f64() * 0.4,
                128 * MIB,
                2_000_000,
                rng.u64(),
            )));
        }
        if rng.bool(0.7) {
            let priority = if rng.bool(0.5) {
                TenantPriority::Guaranteed
            } else {
                TenantPriority::BestEffort
            };
            fleet.push(Box::new(BatchActor::new(
                "batch",
                1,
                (1 + rng.below(30)) * GIB,
                2_000_000,
                2_000_000,
                priority,
                rng.u64(),
            )));
        }
        let mut held: Vec<Lease> = Vec::new();
        let mut t = 0u64;
        for _ in 0..rng.below(60) + 20 {
            match rng.below(6) {
                0..=2 => {
                    let pref = if rng.bool(0.7) {
                        TierPreference::PEER_ONLY
                    } else {
                        TierPreference::FastestAvailable
                    };
                    let durability = if rng.bool(0.5) {
                        harvest::harvest::Durability::Lossy
                    } else {
                        harvest::harvest::Durability::HostBacked
                    };
                    if let Ok(l) = session.alloc(
                        &mut hr,
                        (1 + rng.below(64)) * 64 * MIB,
                        pref,
                        AllocHints { durability, ..hints },
                    ) {
                        held.push(l);
                    }
                }
                3 => {
                    if !held.is_empty() {
                        let l = held.swap_remove(rng.below(held.len() as u64) as usize);
                        session.release(&mut hr, l).map_err(|e| format!("release: {e}"))?;
                    }
                }
                _ => {
                    t += 500_000 + rng.below(2_000_000);
                    fleet.advance_to(&mut hr, t);
                }
            }
            for ev in session.drain_revocations(&mut hr) {
                if ev.action == RevocationAction::Dropped {
                    held.retain(|l| l.id() != ev.lease);
                }
            }
            // per-GPU conservation: tenant segments + harvest leases +
            // in-flight migration sources account for every arena byte
            for g in 0..2 {
                let arena = hr.node.gpus[g].hbm.used();
                let tenant = hr.node.gpus[g].tenant_held;
                let leases = hr.live_bytes_on(g);
                let pending = hr.pending_free_bytes_on_tier(MemoryTier::PeerHbm(g));
                if tenant + leases + pending != arena {
                    return err(format!(
                        "gpu{g}: tenant {tenant} + leases {leases} + pending {pending} \
                         != arena {arena}"
                    ));
                }
            }
            // host-arena conservation via the broker's ledger
            let host = hr.node.host.used();
            let tenant_host = fleet.broker().held_on(&hr, MemoryTier::Host);
            let lease_host = hr.live_bytes_on_tier(MemoryTier::Host);
            let pending_host = hr.pending_free_bytes_on_tier(MemoryTier::Host);
            if tenant_host + lease_host + pending_host != host {
                return err(format!(
                    "host: tenant {tenant_host} + leases {lease_host} + pending \
                     {pending_host} != arena {host}"
                ));
            }
            // tenants always win: an OOM with harvest bytes still live
            // on the tier would break the paper's invariant
            let b = fleet.broker().stats;
            if b.oom_with_harvest > 0 {
                return err(format!(
                    "guaranteed tenant OOMed while harvest held bytes ({b:?})"
                ));
            }
        }
        for l in held.drain(..) {
            session.release(&mut hr, l).map_err(|e| format!("final release: {e}"))?;
        }
        hr.sweep_leaked();
        if hr.live_bytes_on(1) != 0 || hr.live_bytes_on_tier(MemoryTier::Host) != 0 {
            return err("harvest bytes left after teardown".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Expert residency + routing
// ---------------------------------------------------------------------

/// Routing always returns exactly top-k distinct experts in range, for
/// every Table-1 model, across drift epochs.
#[test]
fn prop_router_topk_distinct_in_range() {
    check("router-topk", 60, 0x70CB, |rng| {
        let model = match rng.below(4) {
            0 => find_moe_model("mixtral").unwrap(),
            1 => find_moe_model("phi-3.5").unwrap(),
            2 => find_moe_model("phi-tiny").unwrap(),
            _ => find_moe_model("qwen").unwrap(),
        };
        let mut router =
            RouterSim::new(model, model.n_layers as usize, rng.u64()).with_drift_interval(64);
        for _ in 0..200 {
            let layer = rng.below(model.n_layers) as usize;
            let picks = router.route_token(layer);
            if picks.len() != model.top_k as usize {
                return err(format!("{} picks != top_k {}", picks.len(), model.top_k));
            }
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != picks.len() {
                return err(format!("duplicate experts in {picks:?}"));
            }
            if picks.iter().any(|&e| e >= model.n_experts as usize) {
                return err(format!("expert out of range in {picks:?}"));
            }
        }
        Ok(())
    });
}

/// The rebalancer + revocation keep the residency map consistent: no
/// expert simultaneously local and peer-cached, peer entries always have
/// live handles, and fallback after revocation is host.
#[test]
fn prop_residency_map_consistent_under_revocation() {
    check("residency-consistent", 60, 0x5E51, |rng| {
        let model = find_moe_model("qwen").unwrap();
        let node = SimNode::new(NodeSpec::h100x2());
        let mut hr = HarvestRuntime::new(node, HarvestConfig::for_node(2));
        let offload = 0.2 + 0.6 * rng.f64();
        let mut reb = ExpertRebalancer::new(model, 0, offload);
        reb.rebalance(&mut hr, rng.below(200) as usize + 1);
        reb.residency().check_invariants().map_err(|e| format!("post-rebalance: {e}"))?;
        // revoke a random subset of peer allocations
        let handles: Vec<_> = reb.residency().peer_cached().map(|(_, h, _)| h).collect();
        for h in handles {
            if rng.bool(0.5) {
                hr.revoke(h, RevocationReason::TenantPressure);
            }
        }
        // pull model: the rebalancer repairs its map at the next sync
        reb.sync(&mut hr);
        reb.residency().check_invariants().map_err(|e| format!("post-revoke: {e}"))?;
        // every remaining peer entry must still be live in the runtime
        for (_, h, _) in reb.residency().peer_cached() {
            if !hr.is_live(h) {
                return err(format!("residency references dead handle {h:?}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Schedulers
// ---------------------------------------------------------------------

/// Token conservation: any scheduler, any admission order — every
/// admitted sequence is selected until retired, none is selected after
/// retirement or duplicated within a step.
#[test]
fn prop_scheduler_conserves_sequences() {
    check("sched-conservation", 120, 0x5C4D, |rng| {
        let mut sched: Box<dyn Scheduler> = if rng.bool(0.5) {
            Box::new(Fcfs::new())
        } else {
            Box::new(CompletelyFair::new(1 + rng.below(4) as u32))
        };
        let mut admitted = Vec::new();
        let mut retired = Vec::new();
        let mut next = 0u64;
        for _ in 0..rng.below(200) + 20 {
            match rng.below(4) {
                0 => {
                    let s = SeqId(next);
                    next += 1;
                    sched.admit(s);
                    admitted.push(s);
                }
                1 if !admitted.is_empty() => {
                    let i = rng.below(admitted.len() as u64) as usize;
                    let s = admitted.swap_remove(i);
                    sched.retire(s);
                    retired.push(s);
                }
                _ => {
                    let slots = 1 + rng.below(8) as usize;
                    let picked = sched.select(slots);
                    if picked.len() > slots {
                        return err(format!("{} picked > {slots} slots", picked.len()));
                    }
                    let mut p = picked.clone();
                    p.sort();
                    p.dedup();
                    if p.len() != picked.len() {
                        return err(format!("duplicate seq in step {picked:?}"));
                    }
                    for s in &picked {
                        if retired.contains(s) {
                            return err(format!("{s:?} selected after retire"));
                        }
                        if !admitted.contains(s) {
                            return err(format!("{s:?} selected but never admitted"));
                        }
                    }
                }
            }
            if sched.runnable() != admitted.len() {
                return err(format!(
                    "runnable {} != admitted {}",
                    sched.runnable(),
                    admitted.len()
                ));
            }
        }
        Ok(())
    });
}

/// CF with quantum=1 gives every runnable sequence service within
/// `ceil(n/slots)` steps (no starvation).
#[test]
fn prop_cf_no_starvation() {
    check("cf-no-starvation", 80, 0xFA12, |rng| {
        let n = 2 + rng.below(12) as usize;
        let slots = 1 + rng.below(4) as usize;
        let mut cf = CompletelyFair::new(1);
        for i in 0..n {
            cf.admit(SeqId(i as u64));
        }
        let rounds = n.div_ceil(slots) + 1;
        let mut seen = vec![false; n];
        for _ in 0..rounds {
            for s in cf.select(slots) {
                seen[s.0 as usize] = true;
            }
        }
        if !seen.iter().all(|&b| b) {
            return err(format!("starved sequences within {rounds} rounds: {seen:?}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Workload generator + interconnect
// ---------------------------------------------------------------------

/// Workload generation is deterministic per seed, sorted by arrival, and
/// respects prefix-sharing bounds.
#[test]
fn prop_workload_gen_well_formed() {
    check("workload-gen", 100, 0x3A71, |rng| {
        let spec = WorkloadSpec {
            n_requests: 1 + rng.below(64) as usize,
            mean_prompt_tokens: 16.0 + rng.f64() * 400.0,
            prompt_sigma: 0.2 + rng.f64(),
            max_new_tokens: 1 + rng.below(64) as u32,
            mean_interarrival_ns: rng.below(2) * 1_000_000,
            shared_prefix_fraction: rng.f64(),
            shared_prefix_tokens: rng.below(128) as u32,
            n_prefix_groups: 1 + rng.below(4) as usize,
            seed: rng.u64(),
        };
        let a = WorkloadGen::new(spec).generate();
        let b = WorkloadGen::new(spec).generate();
        if a.len() != spec.n_requests {
            return err(format!("{} requests != {}", a.len(), spec.n_requests));
        }
        for (x, y) in a.iter().zip(&b) {
            if x.prompt_tokens != y.prompt_tokens || x.arrival != y.arrival {
                return err("same seed produced different workloads".into());
            }
        }
        for w in a.windows(2) {
            if w[0].arrival > w[1].arrival {
                return err("arrivals not sorted".into());
            }
        }
        for r in &a {
            if r.prompt_tokens == 0 {
                return err("zero-length prompt".into());
            }
            if r.shared_prefix_tokens > r.prompt_tokens {
                return err(format!(
                    "shared prefix {} > prompt {}",
                    r.shared_prefix_tokens, r.prompt_tokens
                ));
            }
        }
        Ok(())
    });
}

/// Link latency is monotone in transfer size, and NVLink strictly beats
/// PCIe at every size (the Fig. 3 premise).
#[test]
fn prop_link_latency_monotone_and_ordered() {
    check("link-monotone", 60, 0x11C4, |rng| {
        let node = SimNode::new(NodeSpec::h100x2());
        let mut sizes: Vec<u64> = (0..8).map(|_| 1 + rng.below(512 * MIB)).collect();
        sizes.sort_unstable();
        let mut last_p2p = 0;
        let mut last_h2d = 0;
        for &bytes in &sizes {
            let p2p = node.topo.estimate(DeviceId::Gpu(1), DeviceId::Gpu(0), bytes).unwrap();
            let h2d = node.topo.estimate(DeviceId::Host, DeviceId::Gpu(0), bytes).unwrap();
            if p2p >= h2d {
                return err(format!("p2p {p2p} >= h2d {h2d} at {bytes} bytes"));
            }
            if p2p < last_p2p || h2d < last_h2d {
                return err(format!("latency not monotone at {bytes} bytes"));
            }
            last_p2p = p2p;
            last_h2d = h2d;
        }
        Ok(())
    });
}

/// DMA drain-by-tag is a barrier: after drain, no op with that tag is
/// still in flight, and draining never rewinds the clock.
#[test]
fn prop_dma_drain_is_barrier() {
    check("dma-drain", 80, 0xD7A1, |rng| {
        let mut node = SimNode::new(NodeSpec::h100x2());
        let mut tags = Vec::new();
        for t in 0..rng.below(20) + 1 {
            let bytes = 1 + rng.below(64 * MIB);
            let (src, dst) = if rng.bool(0.5) {
                (DeviceId::Host, DeviceId::Gpu(rng.below(2) as usize))
            } else {
                (DeviceId::Gpu(0), DeviceId::Gpu(1))
            };
            let ev = node.copy(src, dst, bytes, Some(t));
            tags.push((t, ev.end));
        }
        let before = node.clock.now();
        let (tag, end) = tags[rng.below(tags.len() as u64) as usize];
        let drained = node.dma.drain_tag(&node.topo, tag);
        if drained < end {
            return err(format!("drained at {drained} < op end {end}"));
        }
        if node.clock.now() < before {
            return err("drain rewound the clock".into());
        }
        if node.dma.tag_busy_until(tag) > node.clock.now() {
            return err("tag still busy after drain".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Cluster: request + byte conservation under routing and spillover
// ---------------------------------------------------------------------

/// Cluster-wide accounting conserves requests and bytes: every arrival
/// is admitted-and-finished or shed exactly once (never both, never
/// twice); the per-node per-tier lease ledgers agree with each node's
/// arena occupancy and sum exactly to the cluster rollup; every node's
/// KV manager invariants hold after the run — under random node counts,
/// router policies, spill/shed thresholds, pool pressure and workloads.
#[test]
fn prop_cluster_conservation() {
    use harvest::cluster::{Cluster, ClusterSpec, RouterPolicy, SchedulerSpec, TierLedger};
    use harvest::server::SimEngineConfig;

    check("cluster-conservation", 24, 0xC1A57E, |rng| {
        let nodes = 1 + rng.below(3) as usize;
        let policy = match rng.below(3) {
            0 => RouterPolicy::RoundRobin,
            1 => RouterPolicy::LeastLoaded,
            _ => RouterPolicy::PrefixAffinity,
        };
        let mut spec = ClusterSpec::new(nodes);
        spec.router = policy;
        spec.spill_queue_depth = 1 + rng.below(8) as usize;
        // Sometimes bound the queues so shedding is exercised.
        spec.shed_queue_depth =
            if rng.bool(0.3) { 2 + rng.below(4) as usize } else { usize::MAX };
        let kv = KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            // small pools force offload through the tier machinery
            local_capacity_blocks: 16 + rng.below(64) as usize,
            use_harvest: rng.bool(0.8),
            host_backed_peer: false,
        };
        let sched = if rng.bool(0.5) {
            SchedulerSpec::Fcfs
        } else {
            SchedulerSpec::CompletelyFair { quantum: 1 + rng.below(3) as u32 }
        };
        let engine =
            SimEngineConfig::new(kv, 2 + rng.below(6) as usize, 4 + rng.below(12) as usize);
        let n_requests = 8 + rng.below(24) as usize;
        let reqs = WorkloadGen::new(WorkloadSpec {
            n_requests,
            mean_prompt_tokens: 48.0 + rng.below(64) as f64,
            max_new_tokens: 4 + rng.below(8) as u32,
            mean_interarrival_ns: if rng.bool(0.5) { 0 } else { 1_000_000 },
            shared_prefix_fraction: if rng.bool(0.5) { 0.6 } else { 0.0 },
            shared_prefix_tokens: 32,
            n_prefix_groups: 1 + rng.below(3) as usize,
            seed: rng.below(1 << 30),
            ..Default::default()
        })
        .generate();
        let tokens_per_request = reqs[0].max_new_tokens as u64;
        let mut cluster = Cluster::new(&spec, engine, sched);
        let report = cluster.run(reqs);

        // -- request conservation: finished + shed == arrivals, each id
        //    in exactly one of {assigned, shed}.
        if report.stats.routed + report.stats.shed != n_requests as u64 {
            return err(format!(
                "routed {} + shed {} != {n_requests}",
                report.stats.routed, report.stats.shed
            ));
        }
        if report.aggregate.requests_finished != report.stats.routed {
            return err(format!(
                "finished {} != routed {} (an admitted request was lost or double-served)",
                report.aggregate.requests_finished, report.stats.routed
            ));
        }
        if report.assignments.len() as u64 != report.stats.routed {
            return err("assignment map disagrees with routed count".into());
        }
        for id in &report.shed {
            if report.assignments.contains_key(id) {
                return err(format!("request {id:?} both shed and assigned"));
            }
        }
        let finished_per_node: u64 = report.per_node.iter().map(|n| n.finished).sum();
        if finished_per_node != report.aggregate.requests_finished {
            return err("per-node finished counts do not sum to the aggregate".into());
        }
        // every finished request generated exactly its token budget
        if report.aggregate.tokens_generated
            != report.aggregate.requests_finished * tokens_per_request
        {
            return err(format!(
                "{} tokens for {} finished requests of {} each",
                report.aggregate.tokens_generated,
                report.aggregate.requests_finished,
                tokens_per_request
            ));
        }

        // -- byte conservation: per-node ledgers match the arenas and
        //    sum to the cluster rollup.
        let mut rollup = TierLedger::default();
        for (i, nr) in report.per_node.iter().enumerate() {
            let node = cluster.node(i);
            let hr = node.runtime();
            let ledger = node.ledger();
            if ledger != nr.ledger {
                return err(format!("node {i}: report ledger {:?} != live {ledger:?}", nr.ledger));
            }
            let arena_peer: u64 =
                (0..hr.node.n_gpus()).map(|g| hr.node.gpus[g].hbm.used()).sum();
            if ledger.peer != arena_peer {
                return err(format!(
                    "node {i}: peer ledger {} != arena used {arena_peer}",
                    ledger.peer
                ));
            }
            if ledger.host != hr.node.host.used() {
                return err(format!(
                    "node {i}: host ledger {} != arena used {}",
                    ledger.host,
                    hr.node.host.used()
                ));
            }
            if ledger.cxl != hr.node.cxl.used() {
                return err(format!(
                    "node {i}: cxl ledger {} != arena used {}",
                    ledger.cxl,
                    hr.node.cxl.used()
                ));
            }
            if ledger.ssd != hr.live_bytes_on_tier(MemoryTier::Ssd) {
                return err(format!(
                    "node {i}: ssd ledger {} != runtime ledger {}",
                    ledger.ssd,
                    hr.live_bytes_on_tier(MemoryTier::Ssd)
                ));
            }
            let by_tier: u64 = (0..hr.node.n_gpus())
                .map(|g| hr.live_bytes_on_tier(MemoryTier::PeerHbm(g)))
                .sum::<u64>()
                + hr.live_bytes_on_tier(MemoryTier::Host)
                + hr.live_bytes_on_tier(MemoryTier::CxlMem)
                + hr.live_bytes_on_tier(MemoryTier::Ssd);
            if by_tier != ledger.total() {
                return err(format!("node {i}: tier sum {by_tier} != ledger {}", ledger.total()));
            }
            if let Err(e) = node.kv_manager().check_invariants() {
                return err(format!("node {i}: kv invariants: {e}"));
            }
            rollup.accumulate(&ledger);
        }
        if rollup != report.ledger {
            return err(format!("rollup {rollup:?} != report ledger {:?}", report.ledger));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Cluster: event-calendar dispatch ordering
// ---------------------------------------------------------------------

/// The event calendar dispatches in nondecreasing virtual time with the
/// laggard scan's exact tie rule (arrivals before node steps, lower
/// node ids first), and lazy invalidation never surfaces a stale node
/// entry. Checked two ways: directly against a shadow model of the heap
/// under random interleavings, and end-to-end over [`Cluster::run`]'s
/// dispatch log — times never decrease, every routed/shed dispatch
/// lands exactly at its request's arrival time in arrival order, and no
/// node ever steps past an arrival that is still waiting to be routed.
#[test]
fn prop_event_calendar_ordering() {
    use harvest::cluster::{Event, EventCalendar};

    check("event-calendar-model", 100, 0xCA1E17DA, |rng| {
        let n_nodes = 1 + rng.below(6) as usize;
        let mut cal = EventCalendar::new(n_nodes);
        // Shadow model: the single live (time, gen) per node, plus the
        // queued arrival times (only the head is ever heaped).
        let mut live: Vec<Option<u64>> = vec![None; n_nodes];
        let mut arrivals: std::collections::VecDeque<u64> = std::collections::VecDeque::new();
        let mut t = 0u64;
        for _ in 0..rng.below(8) + 1 {
            t += rng.below(50);
            arrivals.push_back(t);
        }
        if let Some(&head) = arrivals.front() {
            cal.push_arrival(head);
        }
        let mut last = 0u64;
        let mut clock = 0u64;
        for _ in 0..400 {
            let Some((at, ev)) = cal.pop() else { break };
            if at < last {
                return err(format!("pop went backwards: {at} < {last}"));
            }
            // Arrivals always beat node entries at equal times.
            if let Event::NodeReady(_) = ev {
                if arrivals.front().is_some_and(|&a| a <= at) {
                    return err(format!(
                        "node stepped at {at} past pending arrival {:?}",
                        arrivals.front()
                    ));
                }
            }
            last = at;
            clock = clock.max(at);
            match ev {
                Event::Arrival => {
                    let Some(a) = arrivals.pop_front() else {
                        return err("arrival popped with none queued".into());
                    };
                    if a != at {
                        return err(format!("arrival dispatched at {at}, queued for {a}"));
                    }
                    if let Some(&next) = arrivals.front() {
                        cal.push_arrival(next);
                    }
                    // Routing touches a random node: its pending entry
                    // (if any) goes stale, replaced at >= now.
                    let node = rng.below(n_nodes as u64) as usize;
                    let ready = clock + rng.below(20);
                    live[node] = Some(ready);
                    cal.refresh_node(node, true, ready);
                }
                Event::NodeReady(n) => {
                    match live[n] {
                        Some(want) if want == at => {}
                        other => {
                            return err(format!(
                                "stale entry surfaced: node {n} popped at {at}, model {other:?}"
                            ));
                        }
                    }
                    // Step the node forward; sometimes it drains.
                    clock += 1 + rng.below(10);
                    let still = rng.bool(0.7);
                    live[n] = still.then_some(clock);
                    cal.refresh_node(n, still, clock);
                }
            }
        }
        // Drained calendar means the model is drained too.
        if cal.pop().is_none() && (!arrivals.is_empty() || live.iter().any(Option::is_some)) {
            return err("calendar empty but model still has pending events".into());
        }
        Ok(())
    });

    use harvest::cluster::{Cluster, ClusterSpec, Dispatch, RouterPolicy, SchedulerSpec};
    use harvest::server::SimEngineConfig;

    check("cluster-dispatch-log", 16, 0xD15A7C4, |rng| {
        let nodes = 1 + rng.below(4) as usize;
        let mut spec = ClusterSpec::new(nodes);
        spec.router = match rng.below(3) {
            0 => RouterPolicy::RoundRobin,
            1 => RouterPolicy::LeastLoaded,
            _ => RouterPolicy::PrefixAffinity,
        };
        spec.spill_queue_depth = 1 + rng.below(6) as usize;
        if rng.bool(0.3) {
            spec.shed_queue_depth = 2 + rng.below(4) as usize;
        }
        let kv = KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: 24 + rng.below(48) as usize,
            use_harvest: true,
            host_backed_peer: false,
        };
        let sched = if rng.bool(0.5) {
            SchedulerSpec::Fcfs
        } else {
            SchedulerSpec::CompletelyFair { quantum: 1 }
        };
        let engine = SimEngineConfig::new(kv, 4, 8);
        let n_requests = 8 + rng.below(16) as usize;
        let reqs = WorkloadGen::new(WorkloadSpec {
            n_requests,
            mean_prompt_tokens: 48.0,
            max_new_tokens: 4 + rng.below(6) as u32,
            mean_interarrival_ns: if rng.bool(0.5) { 0 } else { 500_000 },
            shared_prefix_fraction: if rng.bool(0.5) { 0.5 } else { 0.0 },
            shared_prefix_tokens: 32,
            n_prefix_groups: 2,
            seed: rng.below(1 << 30),
            ..Default::default()
        })
        .generate();
        let mut arrival_times: Vec<u64> = reqs.iter().map(|r| r.arrival).collect();
        arrival_times.sort_unstable();
        let mut cluster = Cluster::new(&spec, engine, sched);
        cluster.run(reqs);

        let log = cluster.dispatch_log();
        if log.is_empty() {
            return err("empty dispatch log".into());
        }
        let mut last = 0u64;
        let mut consumed = 0usize;
        for d in log {
            let at = d.at();
            if at < last {
                return err(format!("dispatch time decreased: {at} < {last} ({d:?})"));
            }
            last = at;
            match *d {
                Dispatch::Route { at, .. } | Dispatch::Shed { at } => {
                    // Arrivals dispatch in arrival order, at their own
                    // arrival time.
                    if consumed >= arrival_times.len() {
                        return err("more route/shed dispatches than arrivals".into());
                    }
                    if arrival_times[consumed] != at {
                        return err(format!(
                            "arrival #{consumed} dispatched at {at}, arrived at {}",
                            arrival_times[consumed]
                        ));
                    }
                    consumed += 1;
                }
                Dispatch::Step { at, node } => {
                    if node >= nodes {
                        return err(format!("step on unknown node {node}"));
                    }
                    // No node steps past a pending earlier arrival.
                    if consumed < arrival_times.len() && arrival_times[consumed] < at {
                        return err(format!(
                            "node {node} stepped at {at} past pending arrival {}",
                            arrival_times[consumed]
                        ));
                    }
                }
            }
        }
        if consumed != arrival_times.len() {
            return err(format!("{consumed}/{} arrivals dispatched", arrival_times.len()));
        }
        Ok(())
    });
}

/// Randomized differential: a 1-node cluster run and a bare engine run
/// are bit-for-bit identical — completions, KV counters, tier ledger,
/// step count — across random pools, schedulers, policies and
/// workloads. (The curated matrix lives in `tests/differential.rs`;
/// this is the fuzzed version.)
#[test]
fn prop_single_node_cluster_matches_engine() {
    use harvest::cluster::{Cluster, ClusterSpec, RouterPolicy, SchedulerSpec, TierLedger};
    use harvest::server::{SimEngine, SimEngineConfig};

    check("single-node-differential", 20, 0xD1FF, |rng| {
        let kv = KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            local_capacity_blocks: 24 + rng.below(64) as usize,
            use_harvest: true,
            host_backed_peer: false,
        };
        let sched = if rng.bool(0.5) {
            SchedulerSpec::Fcfs
        } else {
            SchedulerSpec::CompletelyFair { quantum: 1 + rng.below(2) as u32 }
        };
        let engine =
            SimEngineConfig::new(kv, 2 + rng.below(6) as usize, 4 + rng.below(10) as usize);
        let spec = WorkloadSpec {
            n_requests: 8 + rng.below(20) as usize,
            mean_prompt_tokens: 48.0 + rng.below(48) as f64,
            max_new_tokens: 3 + rng.below(8) as u32,
            mean_interarrival_ns: if rng.bool(0.5) { 0 } else { 750_000 },
            shared_prefix_fraction: if rng.bool(0.5) { 0.6 } else { 0.0 },
            shared_prefix_tokens: 32,
            n_prefix_groups: 1 + rng.below(3) as usize,
            seed: rng.below(1 << 30),
            ..Default::default()
        };

        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let mut eng = SimEngine::new(engine, sched.build(), 0);
        let sim = eng.run(&mut hr, WorkloadGen::new(spec).generate());
        let sim_ledger = TierLedger::snapshot(&hr);

        let mut cspec = ClusterSpec::new(1);
        cspec.router = match rng.below(3) {
            0 => RouterPolicy::RoundRobin,
            1 => RouterPolicy::LeastLoaded,
            _ => RouterPolicy::PrefixAffinity,
        };
        let mut cluster = Cluster::new(&cspec, engine, sched);
        let report = cluster.run(WorkloadGen::new(spec).generate());
        let node = &report.per_node[0];

        if sim.completions != node.completions {
            return err(format!(
                "completions diverged: sim {} vs cluster {} entries",
                sim.completions.len(),
                node.completions.len()
            ));
        }
        if sim.kv_stats != node.kv_stats {
            return err(format!(
                "kv stats diverged:\n  sim     {:?}\n  cluster {:?}",
                sim.kv_stats, node.kv_stats
            ));
        }
        if sim_ledger != node.ledger {
            return err(format!(
                "tier ledger diverged: sim {sim_ledger:?} vs cluster {:?}",
                node.ledger
            ));
        }
        if sim.steps != node.steps {
            return err(format!("step counts diverged: {} vs {}", sim.steps, node.steps));
        }
        if sim.metrics.makespan_ns() != report.aggregate.makespan_ns() {
            return err(format!(
                "makespan diverged: {} vs {}",
                sim.metrics.makespan_ns(),
                report.aggregate.makespan_ns()
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// SLO admission control
// ---------------------------------------------------------------------

/// Admission conservation under feedback control: with a controller
/// armed, every generated request is served to completion or shed
/// exactly once — never both, never lost, however deferrals interleave
/// with finishes — the controller's own counters agree with the engine
/// report, and nothing is ever shed while memory pressure sits below the
/// low watermark.
#[test]
fn prop_admission_conservation() {
    use harvest::control::{AdmissionConfig, SloConfig};
    use harvest::server::{SimEngine, SimEngineConfig};

    check("admission-conservation", 30, 0xAD417, |rng| {
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let kv = KvConfig {
            model: find_kv_model("deepseek").unwrap(),
            block_tokens: 16,
            // small pools keep occupancy (and thus deferral) exercised
            local_capacity_blocks: 12 + rng.below(48) as usize,
            use_harvest: rng.bool(0.7),
            host_backed_peer: false,
        };
        let acfg = AdmissionConfig {
            slo: SloConfig {
                ttft_p99_ns: 100_000 + rng.below(50_000_000),
                goodput_floor_tps: if rng.bool(0.3) { 1e9 } else { 0.0 },
                window_ns: 1_000_000 + rng.below(50_000_000),
            },
            high_watermark_pct: 60 + rng.below(35) as u32, // 60..=94
            low_watermark_pct: 20 + rng.below(40) as u32,  // 20..=59
        };
        let cfg = SimEngineConfig::new(kv, 2 + rng.below(4) as usize, 4 + rng.below(8) as usize)
            .with_admission(acfg);
        let sched: Box<dyn Scheduler> = if rng.bool(0.5) {
            Box::new(Fcfs::new())
        } else {
            Box::new(CompletelyFair::new(1 + rng.below(2) as u32))
        };
        let mut eng = SimEngine::new(cfg, sched, 0);
        let n = 8 + rng.below(24) as usize;
        let reqs = WorkloadGen::new(WorkloadSpec {
            n_requests: n,
            mean_prompt_tokens: 48.0 + rng.below(64) as f64,
            max_new_tokens: 4 + rng.below(8) as u32,
            mean_interarrival_ns: rng.below(3) * 400_000,
            seed: rng.below(1 << 30),
            ..Default::default()
        })
        .generate();
        let report = eng.run(&mut hr, reqs);

        let finished = report.metrics.requests_finished;
        let shed = report.sheds.len() as u64;
        if finished + shed != n as u64 {
            return err(format!("finished {finished} + shed {shed} != arrivals {n}"));
        }
        let mut uniq = report.sheds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        if uniq.len() != report.sheds.len() {
            return err(format!("duplicate ids in the shed ledger: {:?}", report.sheds));
        }
        if report.completions.len() as u64 != finished {
            return err(format!(
                "{} completion records for {finished} finishes",
                report.completions.len()
            ));
        }
        for c in &report.completions {
            if report.sheds.contains(&c.id) {
                return err(format!("{:?} both shed and completed", c.id));
            }
        }
        if report.metrics.requests_shed != shed {
            return err(format!(
                "metrics shed {} != ledger {shed}",
                report.metrics.requests_shed
            ));
        }
        let stats = eng.stepper().admission_stats().expect("controller armed");
        if stats.admitted != finished || stats.shed != shed {
            return err(format!(
                "controller counters ({}, {}) disagree with report ({finished}, {shed})",
                stats.admitted, stats.shed
            ));
        }
        // Shedding below the low watermark is forbidden by construction.
        if stats.shed > 0 && stats.min_shed_pressure_pm < acfg.low_watermark_pct * 10 {
            return err(format!(
                "shed at {} pm, below the low watermark {} pm",
                stats.min_shed_pressure_pm,
                acfg.low_watermark_pct * 10
            ));
        }
        // Deferred admissions surfaced in the metrics iff deferrals ran.
        if stats.defer_events == 0 && report.metrics.deferred_admissions > 0 {
            return err("deferred admissions recorded without defer decisions".into());
        }
        Ok(())
    });
}

/// The hysteresis dead band never oscillates: driving the controller
/// with an arbitrary pressure walk, the accepting state flips only when
/// the walk genuinely crosses a watermark (enters at >= high, exits at
/// <= low), enters and exits alternate, and a walk confined strictly
/// inside the (low, high) band never changes state at all.
#[test]
fn prop_admission_hysteresis_no_oscillation() {
    use harvest::control::{AdmissionConfig, AdmissionController, AdmissionSignals, SloConfig};

    check("admission-hysteresis", 120, 0x4F57, |rng| {
        let cfg = AdmissionConfig {
            slo: SloConfig::default(),
            high_watermark_pct: 60 + rng.below(35) as u32, // 60..=94
            low_watermark_pct: 20 + rng.below(40) as u32,  // 20..=59
        };
        let (high_pm, low_pm) = (cfg.high_watermark_pct * 10, cfg.low_watermark_pct * 10);
        let mut ctl = AdmissionController::new(cfg);
        let mut was = ctl.accepting();
        let mut transitions = 0u64;
        let mut t = 0u64;
        for _ in 0..200 {
            t += 1 + rng.below(1_000);
            let pressure = rng.below(1_001) as u32;
            let s = AdmissionSignals {
                occupancy_pm: pressure,
                tenant_pressure_pm: 0,
                queue_depth: rng.below(8) as usize,
                live: rng.below(4) as usize,
            };
            ctl.note_arrival(t);
            if rng.bool(0.5) {
                ctl.note_finish(t, rng.below(100_000), 4);
            }
            let _ = ctl.decide(t, t.saturating_sub(rng.below(1_000)), &s);
            let is = ctl.accepting();
            if is != was {
                transitions += 1;
                if was && pressure < high_pm {
                    return err(format!(
                        "entered Pressured at {pressure} pm, below high {high_pm} pm"
                    ));
                }
                if !was && pressure > low_pm {
                    return err(format!(
                        "exited Pressured at {pressure} pm, above low {low_pm} pm"
                    ));
                }
            }
            was = is;
        }
        let st = ctl.stats();
        if st.pressure_enters + st.pressure_exits != transitions {
            return err(format!(
                "{} + {} state changes recorded, {transitions} observed",
                st.pressure_enters, st.pressure_exits
            ));
        }
        if st.pressure_enters.abs_diff(st.pressure_exits) > 1 {
            return err(format!(
                "enters {} / exits {} do not alternate",
                st.pressure_enters, st.pressure_exits
            ));
        }
        // A walk strictly inside the dead band holds the initial state.
        let mut band = AdmissionController::new(cfg);
        let initial = band.accepting();
        for i in 0..100u64 {
            let p = low_pm + 1 + rng.below(u64::from(high_pm - low_pm - 1)) as u32;
            let s = AdmissionSignals {
                occupancy_pm: p,
                tenant_pressure_pm: 0,
                queue_depth: 2,
                live: 1,
            };
            band.decide(i, i, &s);
            if band.accepting() != initial {
                return err(format!("state flipped inside the dead band at {p} pm"));
            }
        }
        Ok(())
    });
}
