//! Observability differential: obs fully on vs fully off must be
//! bit-for-bit invisible to the simulation.
//!
//! The tracer records *virtual* timestamps, the profiler only reads
//! wall clocks, and the flight recorder only snapshots the trace ring —
//! none of them may perturb a single simulation outcome. These tests
//! run identical workloads with the whole observability plane off and
//! then fully armed (tracing + phase profiling + flight recorder) and
//! require the results to match exactly: per-request completion times,
//! shed ledgers, KV counters, tier ledgers, and — on the cluster path —
//! the full dispatch order.
//!
//! Companion to `tests/differential.rs`, which pins the engine and
//! 1-node-cluster paths to each other; here each path is pinned to its
//! own untraced self.

use std::collections::BTreeSet;

use harvest::cluster::{Cluster, ClusterSpec, Dispatch, RouterPolicy, SchedulerSpec, TierLedger};
use harvest::control::{AdmissionConfig, SloConfig};
use harvest::harvest::{HarvestConfig, HarvestRuntime, PrefetchConfig};
use harvest::kv::{KvConfig, KvStats, SeqId};
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::obs::profile::{self, Phase};
use harvest::obs::trace::{self, Subsystem};
use harvest::obs::{flight, FlightConfig};
use harvest::server::{
    AgingConfig, RequestOutcome, SimEngine, SimEngineConfig, WorkloadGen, WorkloadSpec,
};
use harvest::tenantsim::TenantMix;

fn kv_cfg(cap_blocks: usize) -> KvConfig {
    KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: cap_blocks,
        use_harvest: true,
        host_backed_peer: false,
    }
}

fn admission() -> AdmissionConfig {
    AdmissionConfig {
        slo: SloConfig {
            ttft_p99_ns: 5_000_000,
            goodput_floor_tps: 0.0,
            window_ns: 10_000_000,
        },
        high_watermark_pct: 85,
        low_watermark_pct: 60,
    }
}

/// Arm the whole plane: big trace ring, clean profiler, flight recorder.
fn obs_on() {
    trace::enable(1 << 20);
    profile::reset();
    profile::enable();
    flight::arm(FlightConfig::default());
}

/// Everything off and drained.
fn obs_off() {
    trace::disable();
    profile::disable();
    flight::disarm();
}

/// Everything one engine run must reproduce exactly, traced or not.
#[derive(Debug, PartialEq)]
struct EngineTrace {
    completions: Vec<RequestOutcome>,
    sheds: Vec<SeqId>,
    kv_stats: KvStats,
    ledger: TierLedger,
    steps: u64,
    tokens_generated: u64,
    decode_stall_ns: u64,
    deferred_admissions: u64,
}

/// Overloaded single engine with every instrumented subsystem live:
/// tight pool (harvest transfers), prefetch, idle-aging, and the SLO
/// admission controller under sustained pressure. `attribution` arms
/// the per-request latency ledgers (which must be invisible too).
fn engine_run(attribution: bool) -> EngineTrace {
    let mut hr =
        HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
    let mut cfg = SimEngineConfig::new(kv_cfg(32), 2, 4)
        .with_prefetch(PrefetchConfig::default())
        .with_aging(AgingConfig::default())
        .with_admission(admission());
    if attribution {
        cfg = cfg.with_attribution();
    }
    let mut eng =
        SimEngine::new(cfg, SchedulerSpec::CompletelyFair { quantum: 1 }.build(), 0);
    let spec = WorkloadSpec {
        n_requests: 48,
        mean_prompt_tokens: 128.0,
        max_new_tokens: 16,
        mean_interarrival_ns: 150_000,
        seed: 23,
        ..Default::default()
    };
    let report = eng.run(&mut hr, WorkloadGen::new(spec).generate());
    EngineTrace {
        completions: report.completions,
        sheds: report.sheds,
        kv_stats: report.kv_stats,
        ledger: TierLedger::snapshot(&hr),
        steps: report.steps,
        tokens_generated: report.metrics.tokens_generated,
        decode_stall_ns: report.metrics.decode_stall_ns,
        deferred_admissions: report.metrics.deferred_admissions,
    }
}

#[test]
fn engine_run_bit_identical_with_obs_on() {
    obs_off();
    let base = engine_run(false);

    obs_on();
    let traced = engine_run(false);
    let events = trace::take();
    let prof = profile::snapshot();
    let dumps = flight::take_dumps();
    obs_off();

    assert!(!base.completions.is_empty(), "the case must actually serve requests");
    assert_eq!(base, traced, "tracing+profiling+flight changed a simulation outcome");

    // The traced arm must have actually traced something, across
    // several subsystems, or the equality above proves nothing.
    assert!(!events.is_empty(), "armed run recorded no events");
    let subs: BTreeSet<Subsystem> = events.iter().map(|e| e.sub).collect();
    assert!(
        subs.len() >= 3,
        "engine trace should cover several subsystems, got {subs:?}"
    );
    assert!(subs.contains(&Subsystem::Stepper) && subs.contains(&Subsystem::Admission));

    // The profiler saw every step, and its phase buckets nest inside
    // the total (coverage is a fraction, never an over-count).
    assert_eq!(prof.calls(Phase::Total), traced.steps, "one Total sample per step");
    assert!(prof.coverage() > 0.0 && prof.coverage() <= 1.0);

    // Flight dumps are a side channel; draining them must not have
    // disturbed anything (the equality above already proved it), and
    // the recorder keeps its cap.
    assert!(dumps.len() <= FlightConfig::default().max_dumps);
}

/// A third run after disarming matches the first untraced run — the
/// plane leaves no residue behind once off.
#[test]
fn obs_leaves_no_residue_after_disarm() {
    obs_off();
    let a = engine_run(false);
    obs_on();
    let _ = engine_run(true);
    let _ = trace::take();
    obs_off();
    let b = engine_run(false);
    assert_eq!(a, b, "a traced run left state behind that changed the next run");
}

fn staggered() -> WorkloadSpec {
    WorkloadSpec {
        n_requests: 24,
        mean_prompt_tokens: 64.0,
        max_new_tokens: 8,
        mean_interarrival_ns: 1_000_000,
        shared_prefix_fraction: 0.7,
        shared_prefix_tokens: 32,
        n_prefix_groups: 3,
        seed: 11,
        ..Default::default()
    }
}

/// 4-node calendar path with co-tenants: full report JSON plus the
/// dispatch order. `attribution` arms the per-node latency ledgers
/// (deliberately excluded from the report JSON, so this comparison
/// stays valid on armed runs).
fn cluster_run(attribution: bool) -> (String, Vec<Dispatch>) {
    let mut spec = ClusterSpec::new(4);
    spec.router = RouterPolicy::PrefixAffinity;
    spec.tenants = Some(TenantMix {
        enabled: true,
        training: 1,
        inference: 1,
        batch: 1,
        ..Default::default()
    });
    let mut engine = SimEngineConfig::new(kv_cfg(48), 4, 8).with_aging(AgingConfig::default());
    if attribution {
        engine = engine.with_attribution();
    }
    let mut cluster =
        Cluster::new(&spec, engine, SchedulerSpec::CompletelyFair { quantum: 1 });
    let report = cluster.run(WorkloadGen::new(staggered()).generate());
    (report.to_json().to_string(), cluster.dispatch_log().to_vec())
}

#[test]
fn cluster_run_bit_identical_with_obs_on() {
    obs_off();
    let (base_json, base_dispatch) = cluster_run(false);

    obs_on();
    let (traced_json, traced_dispatch) = cluster_run(false);
    let events = trace::take();
    obs_off();

    assert_eq!(base_json, traced_json, "traced cluster run diverged");
    assert_eq!(base_dispatch, traced_dispatch, "dispatch order changed under tracing");

    // Multi-node attribution: events must carry more than one pid and
    // include the router lane.
    let nodes: BTreeSet<u32> = events.iter().map(|e| e.node).collect();
    assert!(nodes.len() > 1, "4-node trace stuck on one pid: {nodes:?}");
    assert!(
        events.iter().any(|e| e.sub == Subsystem::Router),
        "cluster trace has no router events"
    );
    assert!(
        events.iter().any(|e| e.sub == Subsystem::Tenant),
        "co-tenant run traced no tenant wakes"
    );
}

/// The attribution ledgers are pure observation: an armed engine run
/// must reproduce the unarmed run bit for bit — completion times, shed
/// ledgers, KV counters, tier ledgers, step counts, everything.
#[test]
fn engine_run_bit_identical_with_attribution_on() {
    obs_off();
    let base = engine_run(false);
    let armed = engine_run(true);
    assert!(!base.completions.is_empty(), "the case must actually serve requests");
    assert_eq!(base, armed, "attribution changed a simulation outcome");
}

/// Same on the cluster path: armed per-node ledgers must leave the full
/// report JSON and the calendar dispatch order untouched.
#[test]
fn cluster_run_bit_identical_with_attribution_on() {
    obs_off();
    let (base_json, base_dispatch) = cluster_run(false);
    let (armed_json, armed_dispatch) = cluster_run(true);
    assert_eq!(base_json, armed_json, "attribution changed the cluster report");
    assert_eq!(base_dispatch, armed_dispatch, "attribution changed the dispatch order");
}
