//! Conservation property for the causal attribution layer
//! (`obs/attrib.rs`): on every finished request, the per-component
//! ledgers must decompose the *measured* latencies exactly —
//!
//! * the TTFT components sum bit-exactly to the measured TTFT,
//! * TTFT + the decode components sum bit-exactly to the measured
//!   end-to-end latency,
//! * so `unattributed_ns()` is pinned to 0 (not "≥ 95% coverage" — the
//!   telescoping-cursor design makes the decomposition exhaustive by
//!   construction, and this test is what keeps it that way),
//!
//! across engine configurations that exercise every charge site (tight
//! pools forcing reload stalls, prefetch, idle-aging, SLO admission
//! deferrals), and on the cluster path, where the rollup must equal the
//! per-node sums component by component.

use harvest::cluster::{Cluster, ClusterSpec, RouterPolicy, SchedulerSpec};
use harvest::control::{AdmissionConfig, SloConfig};
use harvest::harvest::{HarvestConfig, HarvestRuntime, PrefetchConfig};
use harvest::kv::KvConfig;
use harvest::memsim::{NodeSpec, SimNode};
use harvest::moe::find_kv_model;
use harvest::obs::{AttributionReport, Component};
use harvest::server::{AgingConfig, SimEngine, SimEngineConfig, WorkloadGen, WorkloadSpec};
use harvest::tenantsim::TenantMix;

fn kv_cfg(cap_blocks: usize) -> KvConfig {
    KvConfig {
        model: find_kv_model("deepseek").unwrap(),
        block_tokens: 16,
        local_capacity_blocks: cap_blocks,
        use_harvest: true,
        host_backed_peer: false,
    }
}

fn admission() -> AdmissionConfig {
    AdmissionConfig {
        slo: SloConfig {
            ttft_p99_ns: 5_000_000,
            goodput_floor_tps: 0.0,
            window_ns: 10_000_000,
        },
        high_watermark_pct: 85,
        low_watermark_pct: 60,
    }
}

/// The conservation invariant, request by request and in rollup.
fn check_conservation(rep: &AttributionReport, label: &str) {
    assert!(!rep.requests.is_empty(), "{label}: no finished requests to check");
    for r in &rep.requests {
        assert_eq!(
            r.ttft_sum(),
            r.ttft_ns,
            "{label}: req {} ttft components do not sum to the measured ttft",
            r.id
        );
        assert_eq!(
            r.ttft_ns + r.decode_sum(),
            r.e2e_ns,
            "{label}: req {} decode components do not close the e2e window",
            r.id
        );
        assert_eq!(r.unattributed_ns(), 0, "{label}: req {} leaked latency", r.id);
    }
    assert_eq!(rep.unattributed_total(), 0, "{label}: rollup leaked latency");
    // The acceptance bar is ≥ 95% of measured latency attributed; exact
    // conservation makes it exactly 100%.
    let measured = rep.e2e_measured_total();
    let attributed = measured - rep.unattributed_total();
    assert!(
        measured == 0 || attributed * 100 >= measured * 95,
        "{label}: attribution coverage below 95%"
    );
}

#[test]
fn prop_attribution_conservation_engine() {
    // (pool blocks, prefetch, aging, admission): tight pools force
    // reload stalls and recomputes; prefetch/aging arm their windows;
    // admission exercises defer/queue-wait accounting.
    let cases = [
        (16usize, false, false, true),
        (32, true, false, true),
        (64, true, true, false),
        (256, false, true, false),
    ];
    for (cap, prefetch, aging, adm) in cases {
        let label = format!("engine cap={cap} prefetch={prefetch} aging={aging} adm={adm}");
        let mut hr =
            HarvestRuntime::new(SimNode::new(NodeSpec::h100x2()), HarvestConfig::for_node(2));
        let mut cfg = SimEngineConfig::new(kv_cfg(cap), 2, 4).with_attribution();
        if prefetch {
            cfg = cfg.with_prefetch(PrefetchConfig::default());
        }
        if aging {
            cfg = cfg.with_aging(AgingConfig::default());
        }
        if adm {
            cfg = cfg.with_admission(admission());
        }
        let sched = SchedulerSpec::CompletelyFair { quantum: 1 }.build();
        let mut eng = SimEngine::new(cfg, sched, 0);
        let spec = WorkloadSpec {
            n_requests: 40,
            mean_prompt_tokens: 128.0,
            max_new_tokens: 12,
            mean_interarrival_ns: 200_000,
            seed: cap as u64,
            ..Default::default()
        };
        let report = eng.run(&mut hr, WorkloadGen::new(spec).generate());
        let attrib = report.attribution.expect("attribution was armed");
        assert_eq!(
            attrib.requests.len() as u64,
            report.metrics.requests_finished,
            "{label}: one ledger per finished request"
        );
        check_conservation(&attrib, &label);
        // Prefill compute is on every request's critical path, so a
        // non-degenerate run must charge it.
        assert!(
            attrib.ttft_total(Component::PrefillCompute) > 0,
            "{label}: no prefill compute attributed"
        );
    }
}

#[test]
fn prop_attribution_conservation_cluster() {
    let mut spec = ClusterSpec::new(4);
    spec.router = RouterPolicy::PrefixAffinity;
    spec.tenants = Some(TenantMix {
        enabled: true,
        training: 1,
        inference: 1,
        batch: 1,
        ..Default::default()
    });
    let engine = SimEngineConfig::new(kv_cfg(48), 4, 8)
        .with_aging(AgingConfig::default())
        .with_attribution();
    let mut cluster = Cluster::new(&spec, engine, SchedulerSpec::CompletelyFair { quantum: 1 });
    let workload = WorkloadSpec {
        n_requests: 32,
        mean_prompt_tokens: 64.0,
        max_new_tokens: 8,
        mean_interarrival_ns: 500_000,
        shared_prefix_fraction: 0.7,
        shared_prefix_tokens: 32,
        n_prefix_groups: 3,
        seed: 11,
        ..Default::default()
    };
    let report = cluster.run(WorkloadGen::new(workload).generate());
    let rollup = report.attribution.as_ref().expect("attribution was armed");
    check_conservation(rollup, "cluster rollup");
    // The rollup is exactly the concatenation of the per-node ledgers:
    // every total matches the per-node sum, component by component.
    let mut ledgers = 0;
    for c in Component::ALL {
        let ttft: u64 = report
            .per_node
            .iter()
            .filter_map(|n| n.attribution.as_ref())
            .map(|a| a.ttft_total(c))
            .sum();
        let decode: u64 = report
            .per_node
            .iter()
            .filter_map(|n| n.attribution.as_ref())
            .map(|a| a.decode_total(c))
            .sum();
        assert_eq!(rollup.ttft_total(c), ttft, "cluster ttft rollup mismatch on {:?}", c);
        assert_eq!(rollup.decode_total(c), decode, "cluster decode rollup mismatch on {:?}", c);
    }
    for n in &report.per_node {
        let a = n.attribution.as_ref().expect("every node was armed");
        check_conservation_allow_empty(a, &format!("node {}", n.node));
        ledgers += a.requests.len();
    }
    assert_eq!(ledgers, rollup.requests.len(), "rollup concatenates the per-node ledgers");
}

/// Per-node slices of a small cluster run may legitimately be empty
/// (an unlucky node served nothing); conservation still must hold for
/// whatever they did serve.
fn check_conservation_allow_empty(rep: &AttributionReport, label: &str) {
    for r in &rep.requests {
        assert_eq!(r.ttft_sum(), r.ttft_ns, "{label}: req {} ttft mismatch", r.id);
        assert_eq!(r.ttft_ns + r.decode_sum(), r.e2e_ns, "{label}: req {} e2e mismatch", r.id);
        assert_eq!(r.unattributed_ns(), 0, "{label}: req {} leaked latency", r.id);
    }
}
