//! Integration: load AOT artifacts through the PJRT CPU client and run
//! real decode steps — proves the python→HLO-text→rust bridge composes.
//!
//! Requires `make artifacts` to have run (skips otherwise, so `cargo test`
//! stays green on a fresh checkout).

use harvest::runtime::{DecodeSlot, ModelRuntime, PjrtRuntime};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

fn page_table_for(seq: usize, mp: usize) -> Vec<i32> {
    // Sequence `seq` owns physical pages [seq*mp, (seq+1)*mp).
    (0..mp).map(|j| (seq * mp + j) as i32).collect()
}

#[test]
fn loads_and_decodes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let mut rt = ModelRuntime::load(&dir).expect("load artifacts");
    let cfg = rt.config().clone();
    assert_eq!(cfg.n_heads * cfg.head_dim, cfg.d_model);

    let mp = cfg.max_pages_per_seq;
    let slots = vec![
        DecodeSlot { token: 5, pos: 0, page_table: page_table_for(0, mp) },
        DecodeSlot { token: 9, pos: 0, page_table: page_table_for(1, mp) },
    ];
    let out = rt.decode(&slots).expect("decode");
    assert_eq!(out.logits.len(), 2);
    assert_eq!(out.logits[0].len(), cfg.vocab);
    assert!(out.logits.iter().flatten().all(|x| x.is_finite()));
    assert_eq!(out.routed.len(), cfg.n_layers);
    for layer in &out.routed {
        assert_eq!(layer.len(), 2);
        for slot in layer {
            assert_eq!(slot.len(), cfg.top_k);
            assert!(slot.iter().all(|&e| (0..cfg.n_experts as i32).contains(&e)));
        }
    }
}

#[test]
fn greedy_decode_is_deterministic_across_runtimes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let run = || {
        let mut rt = ModelRuntime::load(&dir).unwrap();
        let cfg = rt.config().clone();
        let mp = cfg.max_pages_per_seq;
        let mut tok = 7i32;
        let mut toks = vec![tok];
        for t in 0..6 {
            let slots =
                vec![DecodeSlot { token: tok, pos: t, page_table: page_table_for(0, mp) }];
            let out = rt.decode(&slots).unwrap();
            let logits = &out.logits[0];
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            tok = argmax;
            toks.push(tok);
        }
        toks
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert!(a.len() == 7);
}

#[test]
fn batch_padding_does_not_corrupt_real_slots() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let cfg = {
        let rt = ModelRuntime::load(&dir).unwrap();
        rt.config().clone()
    };
    let mp = cfg.max_pages_per_seq;
    // Run the same single sequence twice: once alone (b1 variant), once
    // padded into the b4 variant via 3 dummy slots. Logits must agree.
    let decode_seq = |pad: bool| {
        let mut rt = ModelRuntime::load(&dir).unwrap();
        let mut outs = Vec::new();
        for t in 0..3 {
            let mut slots =
                vec![DecodeSlot { token: 3 + t, pos: t, page_table: page_table_for(0, mp) }];
            if pad {
                // Force the b4 variant by adding real-but-ignored slots on
                // their own pages.
                slots.push(DecodeSlot {
                    token: 1,
                    pos: t,
                    page_table: page_table_for(1, mp),
                });
                slots.push(DecodeSlot {
                    token: 2,
                    pos: t,
                    page_table: page_table_for(2, mp),
                });
            }
            let out = rt.decode(&slots).unwrap();
            outs.push(out.logits[0].clone());
        }
        outs
    };
    let solo = decode_seq(false);
    let padded = decode_seq(true);
    for (a, b) in solo.iter().zip(&padded) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-4, "padding changed logits: {x} vs {y}");
        }
    }
}
